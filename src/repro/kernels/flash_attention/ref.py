"""Pure-jnp oracle for flash attention (GQA + causal + sliding window)."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q (B, S, H, D); k/v (B, T, KV, D) -> (B, S, H, D), fp32 math."""
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qf = q.astype(jnp.float32).reshape(b, s, kvh, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bksgt", qf, kf) * d ** -0.5
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, :, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bksgt,btkd->bskgd", w, vf)
    return out.reshape(b, s, h, d).astype(q.dtype)
