"""repro.sim — deterministic discrete-event geo-fleet simulator.

Replays training schedules produced by ``core.assign`` /
``core.placement.plan_runtime`` over a ``ClusterGraph``, modeling per-link
bandwidth with fair-share contention, per-machine compute with straggler
jitter, pipeline bubbles, DP parameter-server sync, TP all-reduce rings, and
fault events that trigger ``runtime.elastic`` re-planning mid-run.

Calibration contract: with no contention, no jitter and no faults, the
simulated per-step time of each parallelism strategy equals the analytic
``core.cost_model`` prediction (``AlphaBetaComm`` / ``PaperLinearComm`` are
the zero-contention limits of ``sim.network.NetworkModel``) — asserted in
``tests/test_sim.py``.
"""
from repro.sim.colocate import (TenantCompute, canonical_colocated,
                                check_colocated_invariants, run_colocated)
from repro.sim.compute import ComputeModel, JitterConfig
from repro.sim.engine import Simulator
from repro.sim.evaluate import (FleetSimulation, SimResult, comparison_table,
                                evaluate_all, evaluate_scenario,
                                observed_telemetry, observed_telemetry_live,
                                run_drift_scenario, simulate_single)
from repro.sim.generate import (ENVELOPE, approx_params, check_scenario,
                                declared_invariants, generate_scenario,
                                generated_scenarios)
from repro.sim.faults import (FaultPlan, GrayFailure, LinkDegradation,
                              MachineCrash, MachineFlap, RegionPartition,
                              RegionPreemption, compile_plan,
                              plan_from_fracs)
from repro.sim.network import NetworkModel
from repro.sim.scenarios import (COLOCATED_SCENARIOS, DRIFT_SCENARIOS,
                                 SCENARIOS, SERVE_SCENARIOS,
                                 ColocatedScenario, DriftScenario, Scenario,
                                 ServeScenario, get_colocated_scenario,
                                 get_drift_scenario, get_scenario,
                                 get_serve_scenario, register,
                                 register_colocated, register_drift,
                                 register_scenario, register_serve,
                                 temporary_registration, unregister,
                                 unregister_colocated, unregister_drift,
                                 unregister_scenario, unregister_serve)
from repro.sim.workload import ServeExecutor

__all__ = [
    "Simulator", "NetworkModel", "ComputeModel", "JitterConfig",
    "Scenario", "SCENARIOS", "register", "get_scenario",
    "ServeScenario", "SERVE_SCENARIOS", "register_serve",
    "get_serve_scenario", "ServeExecutor",
    "DriftScenario", "DRIFT_SCENARIOS", "register_drift",
    "get_drift_scenario", "unregister_drift", "run_drift_scenario",
    "ColocatedScenario", "COLOCATED_SCENARIOS", "register_colocated",
    "get_colocated_scenario", "unregister_colocated",
    "register_scenario", "unregister_scenario",
    "run_colocated", "canonical_colocated", "check_colocated_invariants",
    "TenantCompute",
    "generate_scenario", "generated_scenarios", "check_scenario",
    "declared_invariants", "approx_params", "ENVELOPE",
    "unregister", "unregister_serve", "temporary_registration",
    "FaultPlan", "MachineCrash", "RegionPreemption", "LinkDegradation",
    "RegionPartition", "GrayFailure", "MachineFlap",
    "compile_plan", "plan_from_fracs",
    "FleetSimulation", "SimResult", "simulate_single",
    "evaluate_scenario", "evaluate_all", "comparison_table",
    "observed_telemetry", "observed_telemetry_live",
]
