"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936; qk_norm, GQA [hf:Qwen/Qwen3-8B family scaling].

long_500k SKIPPED: pure full attention (DESIGN.md SS4).
"""
from repro.configs.base import AttnSpec, LayerSpec, ModelConfig, Segment

_ATTN = AttnSpec(n_heads=64, n_kv_heads=8, head_dim=128, qk_norm=True,
                 rope_theta=1_000_000.0)


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        d_model=5120,
        vocab_size=151_936,
        segments=(
            Segment(count=64,
                    layers=(LayerSpec(kind="attn", mlp="dense", attn=_ATTN,
                                      d_ff=25_600),)),
        ),
        norm="rmsnorm",
        act="silu",
        tie_embeddings=False,
        sub_quadratic=False,
    )
