"""Provenance stamp for every emitted BENCH_*.json.

A benchmark number with no record of what produced it is unreviewable: six
months later nobody can say which commit, seed, or solver mode a cell came
from. ``stamp(res, seed=..., solver_mode=...)`` attaches a ``provenance``
block to a result dict right before it is dumped:

    {"git_sha": "...", "seed": 0, "timestamp": "2026-08-08T12:00:00Z",
     "jax_version": "0.4.x", "solver_mode": "fast+reference",
     "config_hash": "a1b2c3d4e5f6"}

``config_hash`` is the first 12 hex chars of the sha256 over the result's
own ``config`` block (canonical JSON), so two artifacts claiming the same
configuration can be compared by a string equality instead of a field-wise
diff. The wall-clock timestamp is allowed HERE and only here — trace files
(``repro.obs``) must stay byte-identical across same-seed runs, so they
never carry one; benchmark artifacts are wall-clock measurements already.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def git_sha() -> str:
    """HEAD commit of the repo the benchmark ran from; "unknown" outside a
    checkout (e.g. an unpacked source tarball)."""
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=_REPO_ROOT,
                             capture_output=True, text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def jax_version() -> str:
    try:
        import jax
        return jax.__version__
    except Exception:
        return "unavailable"


def config_hash(config) -> str:
    """12-hex-char digest of a config mapping (canonical JSON, so key order
    and whitespace don't matter)."""
    blob = json.dumps(config, sort_keys=True, separators=(",", ":"),
                      default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def provenance(seed=None, solver_mode=None, config=None) -> dict:
    return {
        "git_sha": git_sha(),
        "seed": seed,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "jax_version": jax_version(),
        "solver_mode": solver_mode,
        "config_hash": config_hash(config if config is not None else {}),
    }


def stamp(res: dict, seed=None, solver_mode=None) -> dict:
    """Attach the provenance block to a benchmark result, in place. The
    config hashed is the result's own ``config`` block when present."""
    res["provenance"] = provenance(seed=seed, solver_mode=solver_mode,
                                   config=res.get("config"))
    return res
