"""Pallas TPU blocked SpMM for the GCN aggregation A_hat @ H.

The paper's own compute hot spot (gnn.py `_aggregate`): the (n x n)
adjacency-by-features product inside every edge-pool / GCN layer. Fleet
graphs are dense-small (n <= a few thousand machines), so the TPU-native
form is a *masked dense* blocked matmul: (BI x BK) adjacency tiles stream
against (BK x D) feature tiles with an fp32 VMEM accumulator over the K grid
dim — MXU-shaped (128-multiple) tiles rather than a GPU-style
gather/scatter SpMM, which does not map to the systolic array (DESIGN.md
SS3 hardware adaptation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_I = 128
DEFAULT_BLOCK_K = 128


def _spmm_kernel(a_ref, h_ref, o_ref, acc_scr):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    a = a_ref[...].astype(jnp.float32)              # (BI, BK)
    h = h_ref[...].astype(jnp.float32)              # (BK, D)
    acc_scr[...] += jax.lax.dot_general(
        a, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def spmm_blocked(adj, feats, *, block_i: int = DEFAULT_BLOCK_I,
                 block_k: int = DEFAULT_BLOCK_K, interpret: bool = True):
    """adj (N, N), feats (N, D) -> (N, D); N multiple of blocks, D
    lane-aligned (ops.py pads)."""
    n, d = feats.shape
    ni, nk = adj.shape[0] // block_i, n // block_k
    return pl.pallas_call(
        functools.partial(_spmm_kernel),
        grid=(ni, nk),
        in_specs=[
            pl.BlockSpec((block_i, block_k), lambda i, k: (i, k)),
            pl.BlockSpec((block_k, d), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, d), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((adj.shape[0], d), feats.dtype),
        scratch_shapes=[pltpu.VMEM((block_i, d), jnp.float32)],
        interpret=interpret,
    )(adj, feats)


def _scaled_spmm_kernel(a_ref, h_ref, r_ref, c_ref, o_ref, acc_scr):
    """diag(r) @ A @ diag(c) @ H fused into the tile loop: the column scale
    multiplies each adjacency tile before it hits the MXU, the row scale
    multiplies the fp32 accumulator once on the last K step — the normalized
    (N, N) matrix is never materialized."""
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    a = a_ref[...].astype(jnp.float32)              # (BI, BK)
    c = c_ref[...].astype(jnp.float32)              # (1, BK)
    h = h_ref[...].astype(jnp.float32)              # (BK, D)
    acc_scr[...] += jax.lax.dot_general(
        a * c, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        r = r_ref[...].astype(jnp.float32)          # (BI, 1)
        o_ref[...] = (acc_scr[...] * r).astype(o_ref.dtype)


def scaled_spmm_blocked(adj, feats, row_scale, col_scale, *,
                        block_i: int = DEFAULT_BLOCK_I,
                        block_k: int = DEFAULT_BLOCK_K, interpret: bool = True):
    """(diag(row_scale) @ adj @ diag(col_scale)) @ feats in one pass.

    adj (M, N), feats (N, D), row_scale (M, 1), col_scale (1, N); blocks
    divide M/N and D is lane-aligned (ops.py pads)."""
    n, d = feats.shape
    ni, nk = adj.shape[0] // block_i, n // block_k
    return pl.pallas_call(
        functools.partial(_scaled_spmm_kernel),
        grid=(ni, nk),
        in_specs=[
            pl.BlockSpec((block_i, block_k), lambda i, k: (i, k)),
            pl.BlockSpec((block_k, d), lambda i, k: (k, 0)),
            pl.BlockSpec((block_i, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((1, block_k), lambda i, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((block_i, d), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((adj.shape[0], d), feats.dtype),
        scratch_shapes=[pltpu.VMEM((block_i, d), jnp.float32)],
        interpret=interpret,
    )(adj, feats, row_scale, col_scale)
