"""Benchmark harness: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``

Prints a ``name,seconds,derived`` CSV row per artifact and dumps the full
JSON to benchmarks/results.json. Roofline numbers live in the dry-run
(launch.dryrun) because they need the 512-device lowering.
"""
from __future__ import annotations

import json
import os
import sys
import time


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks import (paper_artifacts, kernel_bench, sim_bench,
                            plan_bench, serve_bench, fleet_bench,
                            label_bench, chaos_bench, online_bench,
                            mix_bench)

    results = []
    print("name,seconds,derived")
    for fn in (list(paper_artifacts.ALL) + list(kernel_bench.ALL)
               + list(sim_bench.ALL) + list(plan_bench.ALL)
               + list(serve_bench.ALL) + list(fleet_bench.ALL)
               + list(label_bench.ALL) + list(chaos_bench.ALL)
               + list(online_bench.ALL) + list(mix_bench.ALL)):
        t0 = time.time()
        res = fn()
        dt = time.time() - t0
        if "provenance" not in res:   # artifacts that don't self-stamp
            from benchmarks._provenance import stamp
            stamp(res, seed=0, solver_mode="fast")
        results.append(res)
        print(f"{res['artifact']},{dt:.1f},{res.get('derived', '')}")

    # headline: the paper's >20% claim must reproduce
    fig8 = next(r for r in results if r["artifact"] == "fig8")
    fig10 = next(r for r in results if r["artifact"] == "fig10")
    ok = (fig8["improvement_vs_best_baseline"] > 0.20
          and fig10["improvement_vs_best_baseline"] > 0.20)
    print(f"\npaper_claim_>20%_improvement:"
          f" fig8={fig8['improvement_vs_best_baseline']:.1%}"
          f" fig10={fig10['improvement_vs_best_baseline']:.1%}"
          f" -> {'PASS' if ok else 'FAIL'}")

    out = os.path.join(os.path.dirname(__file__), "results.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
