from repro.data.synthetic import (SyntheticConfig, SyntheticLM, batch_struct,
                                  make_batch)

__all__ = ["SyntheticConfig", "SyntheticLM", "batch_struct", "make_batch"]
