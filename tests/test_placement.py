"""core.placement (pod bridge) and cost_model.routed_latency coverage."""
import dataclasses

import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core import placement
from repro.core.graph import ClusterGraph, Machine


# ---------------------------------------------------------------------------
# routed_latency
# ---------------------------------------------------------------------------
def test_routed_latency_relays_blocked_pair():
    # 0 -- 1 -- 2 chain; 0<->2 policy-blocked: traffic relays via 1
    lat = np.array([[0.0, 10.0, 0.0],
                    [10.0, 0.0, 15.0],
                    [0.0, 15.0, 0.0]], np.float32)
    routed = cm.routed_latency(lat)
    assert routed[0, 2] == pytest.approx(25.0)
    assert routed[2, 0] == pytest.approx(25.0)
    # direct links keep their latency (no shorter relay exists)
    assert routed[0, 1] == pytest.approx(10.0)


def test_routed_latency_prefers_cheaper_relay():
    # direct 0->2 exists but the relay through 1 is cheaper
    lat = np.array([[0.0, 5.0, 100.0],
                    [5.0, 0.0, 5.0],
                    [100.0, 5.0, 0.0]], np.float32)
    routed = cm.routed_latency(lat)
    assert routed[0, 2] == pytest.approx(10.0)


def test_routed_latency_disconnected_pair_stays_blocked():
    # node 2 has no links at all: the pair stays 0 ("cannot communicate")
    lat = np.array([[0.0, 10.0, 0.0],
                    [10.0, 0.0, 0.0],
                    [0.0, 0.0, 0.0]], np.float32)
    routed = cm.routed_latency(lat)
    assert routed[0, 2] == 0.0
    assert routed[1, 2] == 0.0
    assert routed[0, 1] == pytest.approx(10.0)
    assert np.all(np.diag(routed) == 0.0)


# ---------------------------------------------------------------------------
# choose_pod_strategy
# ---------------------------------------------------------------------------
def test_single_pod_is_dp_with_no_cross_pod_traffic():
    for task in (cm.OPT_175B, cm.BERT_LARGE):
        strat, nbytes = placement.choose_pod_strategy(task, n_pods=1)
        assert strat == "dp"
        assert nbytes == 0.0
    strat, nbytes = placement.choose_pod_strategy(cm.BERT_LARGE, n_pods=0)
    assert (strat, nbytes) == ("dp", 0.0)


def test_small_model_prefers_dp_large_model_prefers_pipeline():
    # BERT: 0.68 GB of weights vs GBs of activations -> DP sync is cheaper
    strat, nbytes = placement.choose_pod_strategy(cm.BERT_LARGE, n_pods=4)
    assert strat == "dp"
    assert nbytes == pytest.approx(2 * cm.BERT_LARGE.param_bytes * 3 / 4)
    # OPT-175B: 350 GB of weights dwarf the activations -> pipeline wins
    strat, nbytes = placement.choose_pod_strategy(cm.OPT_175B, n_pods=4)
    assert strat == "pipeline"
    assert nbytes == pytest.approx(
        2 * cm.OPT_175B.microbatches * cm.OPT_175B.act_bytes_per_microbatch * 3)


def test_dp_pipeline_crossover_point():
    """Scaling params at fixed activation size flips DP -> pipeline exactly
    where ring-all-reduce bytes overtake boundary-activation bytes."""
    base = cm.ModelTask("x", 1e9, 24, 1024, batch_tokens=65_536,
                        microbatches=8)
    n = 4
    pp_bytes = 2 * base.microbatches * base.act_bytes_per_microbatch * (n - 1)
    # params such that dp_bytes == pp_bytes (dp wins ties)
    crossover_params = pp_bytes * n / (n - 1) / 2 / base.dtype_bytes
    at = dataclasses.replace(base, params=crossover_params)
    above = dataclasses.replace(base, params=crossover_params * 1.01)
    assert placement.choose_pod_strategy(at, n)[0] == "dp"
    assert placement.choose_pod_strategy(above, n)[0] == "pipeline"


# ---------------------------------------------------------------------------
# pods_as_graph after the Machine capability-override refactor
# ---------------------------------------------------------------------------
def test_pods_as_graph_carries_pod_capabilities():
    pods = [placement.PodSpec("pod0", "California", chips=256),
            placement.PodSpec("pod1", "Tokyo", chips=128,
                              tflops_per_chip=459.0, hbm_gb_per_chip=32.0)]
    lat = np.array([[0.0, 118.8], [118.8, 0.0]], np.float32)
    g = placement.pods_as_graph(pods, lat)
    np.testing.assert_allclose(g.memory_gb(), [16.0 * 256, 32.0 * 128])
    np.testing.assert_allclose(g.tflops(), [197.0 * 256, 459.0 * 128])
    # no monkey-patched bound methods: the dataclass carries the truth
    assert "memory_gb" not in vars(g) and "tflops" not in vars(g)
    # features see the pod values too (memory is no longer the placeholder's)
    feats = g.node_features()
    assert feats[0, -1] == pytest.approx(16.0 * 256 / 512.0)
    assert 0.0 < feats[0, -2] <= 1.0  # capability clamped into feature range


def test_machine_from_caps_and_catalog_agree():
    cat = Machine("Tokyo", "A100", 8)
    custom = Machine.from_caps("Tokyo", capability=cat.capability,
                               memory_gb=cat.memory_gb, tflops=cat.tflops)
    assert custom.memory_gb == cat.memory_gb
    assert custom.tflops == cat.tflops
    assert custom.capability == cat.capability
    g = ClusterGraph([cat, custom],
                     np.array([[0.0, 1.0], [1.0, 0.0]], np.float32))
    np.testing.assert_allclose(g.memory_gb()[0], g.memory_gb()[1])
    np.testing.assert_allclose(g.tflops()[0], g.tflops()[1])
