from repro.analysis.roofline import (HW, collective_bytes, roofline_report,
                                     model_flops)

__all__ = ["HW", "collective_bytes", "roofline_report", "model_flops"]
