"""Step executors: training-step event DAGs + the serving request executor.

Training: each executor simulates ONE training step of a task on its machine
group and reports ``done_cb(compute_phase_s, comm_phase_s)``. The DAG shapes
are chosen so that, with zero jitter and no competing traffic, the simulated
step time equals the analytic ``core.cost_model`` prediction *exactly*:

* ``gpipe`` — an (S stages x M microbatches) wavefront where every op takes
  ``T_c / M`` (stage sizes are proportional to machine compute, so per-stage
  times are equal); the wavefront makespan is ``(M + S - 1) * T_c / M``
  = ``T_c * (1 + (S-1)/M)`` — the bubble formula. The 2M activation/gradient
  boundary transfers per hop then run as a serial chain, matching the
  analytic sum (the paper's model assumes no comm/compute overlap; the
  simulator keeps that assumption and adds contention on top).
* ``dp``    — parallel compute barrier, then all workers exchange 2 x P bytes
  with the parameter server concurrently (server chosen by
  ``cost_model.dp_best_server``); the join is the analytic worst-worker max.
* ``tp``    — parallel compute barrier, then ``4 * n_layers`` sequential ring
  all-reduces; each all-reduce is a concurrent barrier over the ring hops, so
  its zero-contention duration is the analytic worst-hop time.

Under contention (shared links, relay hubs), stragglers (compute jitter) and
re-plans these DAGs diverge from the closed form — that divergence is the
quantity the simulator exists to measure.

Serving (``ServeExecutor``): requests from ``serve.traffic`` flow as
first-class events — arrival at the region's entry node, a routed network
transfer of the prompt, continuous-batching iterations on a
``serve.replica.Replica``, the response transfer back — so serving latency
inherits every fleet effect the training DAGs see (fair-share link
contention, relay hubs, stragglers, diurnal capacity squeeze). Replica
failures re-route interrupted requests; the ``serve.autoscale`` controller
scales the replica set, provisioning spare machines into the live graph
(``NetworkModel.add_machine`` / ``ComputeModel.add_machine``) with a
cold-start weight transfer from the nearest live replica, and — under the
Hulk policy — re-planning placement through
``runtime.elastic.ElasticRuntime.on_join``. Scale-downs deprovision: once
the drained replica goes idle its machine is tombstoned out of the network
and compute models (``remove_machine``), and a later scale-up revives it.

``data_plane="fast"`` (default) runs the fleet-scale request path: the
vectorized dirty-link flow solver, a cached healthy-replica list, router
entry/score caches invalidated on replica-set or topology changes, and the
replicas' O(1) integer-counter backlog. ``data_plane="reference"`` selects
the kept reference implementations (per-event rebalance loop, O(queue)
backlog sweep) — ``benchmarks/fleet_bench.py`` drives both and asserts
equivalence.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np

from repro import obs as obs_mod
from repro.core import cost_model as cm
from repro.core.graph import ClusterGraph, Machine
from repro.sim import faults as faults_mod
from repro.sim.compute import ComputeModel, JitterConfig
from repro.sim.engine import Barrier, Simulator
from repro.sim.network import NetworkModel

DoneCb = Callable[[float, float], None]

# tags keep the counter-based jitter RNG streams of distinct phases disjoint
_TAG_PIPE, _TAG_DP, _TAG_TP = 1, 2, 3


def analytic_step_time(graph: ClusterGraph, ids: Sequence[int],
                       task: cm.ModelTask, comm, strategy: str,
                       order: Sequence[int] | None = None) -> tuple[float, float]:
    """(comm_s, compute_s) the cost model predicts for this placement — used
    both for feasibility checks (inf => don't simulate) and calibration."""
    if strategy == "dp":
        return cm.dp_time(graph, ids, task, comm)
    if strategy == "tp":
        return cm.tp_time(graph, ids, task, comm)
    order = list(order) if order is not None else cm.greedy_chain_order(graph, ids)
    return cm.gpipe_time(graph, ids, task, comm, order)


def run_step(sim: Simulator, net: NetworkModel, compute: ComputeModel,
             graph: ClusterGraph, task: cm.ModelTask, ids: Sequence[int],
             strategy: str, order: Sequence[int], step: int,
             done_cb: DoneCb, comm=None) -> None:
    """``comm`` is the analytic comm model for ``graph`` (used by DP to place
    the parameter server); pass the one you already built — constructing it
    here would redo the all-pairs shortest-path routing every step."""
    if strategy == "dp":
        if comm is None:
            comm = cm.make_comm(graph, net.comm_model)
        _dp_step(sim, net, compute, graph, task, ids, step, done_cb, comm)
    elif strategy == "tp":
        _tp_step(sim, net, compute, graph, task, ids, step, done_cb)
    elif strategy == "gpipe":
        _gpipe_step(sim, net, compute, graph, task, order, step, done_cb)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")


# ---------------------------------------------------------------------------
# GPipe
# ---------------------------------------------------------------------------
def _gpipe_step(sim, net, compute, graph, task, order, step, done_cb):
    order = list(order)
    s_n, m_n = len(order), task.microbatches
    tf = graph.tflops()
    total_tf = float(sum(tf[i] for i in order))
    t0 = sim.now

    if s_n == 1:
        # degenerate chain: M serial microbatches, no boundary traffic
        work = task.flops_per_step / m_n
        def run_mb(m: int):
            if m == m_n:
                done_cb(sim.now - t0, 0.0)
                return
            sim.schedule(compute.duration(order[0], work, step, m, _TAG_PIPE),
                         run_mb, m + 1)
        run_mb(0)
        return

    # stage sizes proportional to machine compute => equal per-op base times
    deps = np.zeros((s_n, m_n), np.int32)
    deps[1:, :] += 1
    deps[:, 1:] += 1

    def comm_phase():
        t1 = sim.now
        hops = list(zip(order[:-1], order[1:]))
        # per hop: M forward activations a->b, M backward gradients b->a —
        # the duplex directions matter because the network model contends
        # each direction separately (latency/bandwidth are symmetric, so the
        # zero-contention serial sum still matches the analytic model)
        transfers = [t for a, b in hops
                     for t in [(a, b)] * m_n + [(b, a)] * m_n]

        def next_transfer(k: int):
            if k == len(transfers):
                done_cb(t1 - t0, sim.now - t1)
                return
            a, b = transfers[k]
            net.transfer(sim, a, b, task.act_bytes_per_microbatch,
                         lambda: next_transfer(k + 1))
        next_transfer(0)

    barrier = Barrier(s_n * m_n, comm_phase)

    def finish_op(s: int, m: int):
        barrier.arrive()
        for (cs, mm) in ((s + 1, m), (s, m + 1)):
            if cs < s_n and mm < m_n:
                deps[cs, mm] -= 1
                if deps[cs, mm] == 0:
                    start_op(cs, mm)

    def start_op(s: int, m: int):
        machine = order[s]
        work = task.flops_per_step * (float(tf[machine]) / total_tf) / m_n
        sim.schedule(compute.duration(machine, work, step, m, _TAG_PIPE),
                     finish_op, s, m)

    start_op(0, 0)


# ---------------------------------------------------------------------------
# Data parallelism (parameter server)
# ---------------------------------------------------------------------------
def _dp_step(sim, net, compute, graph, task, ids, step, done_cb, comm):
    fit = cm._fits_whole_model(graph, ids, task)
    tf = graph.tflops()
    total_tf = float(sum(tf[i] for i in fit))
    server, _ = cm.dp_best_server(fit, task, comm)
    t0 = sim.now

    def comm_phase():
        t1 = sim.now
        workers = [i for i in fit if i != server]
        sync = Barrier(len(workers), lambda: done_cb(t1 - t0, sim.now - t1))
        for i in workers:
            net.transfer(sim, i, server, 2.0 * task.param_bytes, sync.arrive)

    barrier = Barrier(len(fit), comm_phase)
    for i in fit:
        work = task.flops_per_step * (float(tf[i]) / total_tf)
        sim.schedule(compute.duration(i, work, step, 0, _TAG_DP),
                     barrier.arrive)


# ---------------------------------------------------------------------------
# Tensor parallelism (ring all-reduce per layer)
# ---------------------------------------------------------------------------
def _tp_step(sim, net, compute, graph, task, ids, step, done_cb):
    ids = list(ids)
    n = len(ids)
    tf = graph.tflops()
    total_tf = float(sum(tf[i] for i in ids))
    act = task.act_bytes_per_microbatch * task.microbatches
    ring_bytes = act * 2.0 * (n - 1) / max(n, 1)
    rounds = 4 * task.n_layers
    t0 = sim.now

    def comm_phase():
        t1 = sim.now
        if n == 1:
            done_cb(t1 - t0, 0.0)
            return

        def all_reduce(r: int):
            if r == rounds:
                done_cb(t1 - t0, sim.now - t1)
                return
            ring = Barrier(n, lambda: all_reduce(r + 1))
            for k in range(n):
                net.transfer(sim, ids[k], ids[(k + 1) % n], ring_bytes,
                             ring.arrive)
        all_reduce(0)

    barrier = Barrier(n, comm_phase)
    for i in ids:
        work = task.flops_per_step * (float(tf[i]) / total_tf)
        sim.schedule(compute.duration(i, work, step, 0, _TAG_TP),
                     barrier.arrive)


# ---------------------------------------------------------------------------
# Serving executor: requests as first-class events
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RequestRecord:
    """End-to-end bookkeeping for one request."""
    req: "object"                       # serve.traffic.Request
    t_complete: Optional[float] = None
    latency_s: Optional[float] = None
    t_first_token: Optional[float] = None
    n_routes: int = 0
    dropped: bool = False
    drop_reason: Optional[str] = None   # max_routes|unreachable|deadline|retry_budget
    retries: int = 0                    # timeout-driven re-dispatches
    hedges: int = 0                     # speculative extra attempts launched
    machines: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Attempt:
    """One dispatch of a request onto a replica (resilient path): the unit
    the retry timeout, the hedging race and the breaker account in. ``done``
    attempts are inert — every callback that might fire late (timeout,
    prompt delivery, replica completion) checks it first, which is what
    makes 'completes or drops exactly once' a local invariant."""
    rep: object                         # serve.replica.Replica
    t_start: float
    hedged: bool = False
    seq: object = None                  # set once admitted at the replica
    done: bool = False
    timeout_ev: object = None


class ServeExecutor:
    """Drive one routing policy through one serving workload.

    Construction wires the placement (static for the baseline policies,
    ``serve.router.HulkPlacement`` for ``policy="hulk"``), the router, the
    replica set, the optional autoscaler and the fault schedule; ``run()``
    returns the records plus infrastructure stats for
    ``serve.evaluate.summarize``.
    """

    MAX_ROUTES = 5       # re-route attempts before a request is dropped

    def __init__(self, graph: ClusterGraph, model, trace: Sequence,
                 policy: str, *, params=None, cfg=None,
                 comm_model: str = "alphabeta",
                 jitter: Optional[JitterConfig] = None,
                 n_replicas: int = 2, max_batch: int = 8,
                 prefill_chunk: int = 256,
                 autoscale=None, spares: Sequence[Machine] = (),
                 fault_fracs: Sequence[float] = (), kills_per_fault: int = 1,
                 fault_plan=None, resilience=None,
                 max_routes: Optional[int] = None,
                 seed: int = 0, run_until_s: Optional[float] = None,
                 data_plane: str = "fast", obs=None,
                 sim=None, net=None, compute=None,
                 external_load=None):
        from repro.serve.autoscale import Autoscaler
        from repro.serve.replica import Replica
        from repro.serve.resilience import CircuitBreaker
        from repro.serve.router import HulkPlacement, Router, StaticPlacement

        self.obs = obs if obs is not None else obs_mod.NULL
        self.graph = graph
        self.model = model
        self.trace = list(trace)
        self.policy = policy
        self.seed = seed
        self.max_batch = max_batch
        self.prefill_chunk = prefill_chunk
        self.kills_per_fault = kills_per_fault
        self.max_routes = (int(max_routes) if max_routes is not None
                           else int(self.MAX_ROUTES))
        self._Replica = Replica

        if data_plane not in ("fast", "reference"):
            raise ValueError(f"unknown data plane {data_plane!r}")
        self.data_plane = data_plane
        # shared-fleet (colocated) mode: adopt an externally owned engine +
        # network/compute planes so a training tenant contends on the same
        # fabric. The caller (sim.colocate) is responsible for building the
        # shared NetworkModel with the matching solver/data plane.
        self._shared = any(m is not None for m in (sim, net, compute))
        if self._shared and (sim is None or net is None or compute is None):
            raise ValueError("shared-fleet mode needs all of sim=, net= and "
                             "compute=")
        self.sim = sim if sim is not None else Simulator(obs=self.obs)
        self.net = net if net is not None else NetworkModel(
            graph, comm_model, solver=data_plane, obs=self.obs)
        self.compute = compute if compute is not None else ComputeModel(
            graph, jitter, seed=seed)

        if policy == "hulk":
            if params is None or cfg is None:
                raise ValueError("hulk policy needs trained GNN (params, cfg)")
            self.placement = HulkPlacement(graph, model, n_replicas, params,
                                           cfg, external_load=external_load)
        else:
            self.placement = StaticPlacement(graph, model, n_replicas)
        self.router = Router(policy, graph, self.net,
                             scores=getattr(self.placement, "scores", None))

        self.replicas: dict[int, Replica] = {}
        self.retired: list[Replica] = []
        for mid in self.placement.desired():
            self._add_replica(mid)

        self.records = {r.rid: RequestRecord(req=r) for r in self.trace}
        self.horizon = (max(r.t_arrival for r in self.trace)
                        if self.trace else 0.0)
        self.run_until = (run_until_s if run_until_s is not None
                          else 8.0 * max(self.horizon, 1.0) + 600.0)
        self.fault_fracs = tuple(fault_fracs)
        # the legacy fields are a thin shim over the fault plan: each
        # fraction becomes one drawn-victim MachineCrash, compiled to the
        # exact event schedule (and rng keys) the old loop produced
        if fault_plan is None and self.fault_fracs:
            fault_plan = faults_mod.plan_from_fracs(self.fault_fracs,
                                                    kills_per_fault)
        self.fault_plan = fault_plan if fault_plan else None

        # resilience policies (serve.resilience.ResilienceConfig); None = the
        # legacy blind-reroute path, bit-identical to pre-chaos behavior
        self.resilience = resilience
        self._breaker = (CircuitBreaker(resilience.breaker)
                         if resilience is not None
                         and resilience.breaker is not None else None)
        self._attempts: dict[int, list[_Attempt]] = {}
        self._pending_retry: dict[int, int] = {}

        self.scale_log: list[dict] = []
        self._spares = collections.deque(spares)

        # machines whose cold-start weight transfer is still in flight —
        # they count against the autoscaler's replica cap (else every tick
        # past the cooldown re-provisions while slow WAN transfers run) and
        # a scale-down can abort them before they open
        self._provisioning: set[int] = set()
        self._cancelled_starts: set[int] = set()
        # per-request fast path: the healthy-replica list is cached between
        # replica-set changes instead of being rebuilt for every arrival
        self._rep_cache: Optional[list] = None

        self.autoscaler = None
        if autoscale is not None:
            self.autoscaler = Autoscaler(
                self.sim, autoscale,
                n_replicas=lambda: (sum(r.alive for r in
                                        self.replicas.values())
                                    + len(self._provisioning)),
                pending_per_replica=self._pending_per_replica,
                scale_up=self._scale_up, scale_down=self._scale_down)

    # -- replica lifecycle ---------------------------------------------------
    def _routing_changed(self) -> None:
        """The replica set (or topology) changed: drop the cached replica
        list and every router-side score/entry cache."""
        self._rep_cache = None
        self.router.invalidate()

    def _replica_list(self) -> list:
        if self._rep_cache is None:
            self._rep_cache = list(self.replicas.values())
        return self._rep_cache

    def _add_replica(self, mid: int) -> None:
        mem = float(self.graph.memory_gb()[mid])
        self.replicas[mid] = self._Replica(
            self.sim, self.compute, mid, self.model, mem,
            max_batch=self.max_batch, prefill_chunk=self.prefill_chunk,
            reference_backlog=self.data_plane == "reference", obs=self.obs)
        self._routing_changed()

    def _cold_start(self, mid: int) -> None:
        """Weights stream from the nearest live replica (or appear instantly
        when this is the very first one), then the replica opens — unless a
        scale-down cancelled the start while the transfer was in flight."""
        # routed_ms uses 0 as the unreachable sentinel, so filter on
        # reachability BEFORE taking the min (else a partitioned peer
        # looks like the closest one)
        peers = [m for m, r in self.replicas.items()
                 if r.alive and self.net.reachable(m, mid)]
        src = min(peers, key=lambda m: float(self.net.routed_ms[m, mid])) \
            if peers else mid
        self._provisioning.add(mid)
        t_cs = self.sim.now

        def up() -> None:
            if self.obs.enabled:
                self.obs.trace.async_span(
                    f"replica/{mid}", "cold_start", f"cs{mid}", t_cs,
                    self.sim.now, cat="serve",
                    args={"src": src,
                          "bytes": float(self.model.weight_bytes)})
                self.obs.metrics.inc("serve.cold_starts")
                self.obs.metrics.observe("serve.cold_start_s",
                                         self.sim.now - t_cs)
            self._provisioning.discard(mid)
            if mid in self._cancelled_starts:
                self._cancelled_starts.discard(mid)
                self.scale_log.append({"t": self.sim.now,
                                       "event": "replica_start_aborted",
                                       "machine": mid})
                # the machine was released while its weights streamed: it
                # must not linger as a live relay/entry candidate
                self._deprovision(mid)
                return
            old = self.replicas.get(mid)
            if old is not None:
                self.retired.append(old)
            self._add_replica(mid)
            self.scale_log.append({"t": self.sim.now, "event": "replica_up",
                                   "machine": mid})
        self.net.transfer(self.sim, src, mid, self.model.weight_bytes, up)

    def _pending_per_replica(self) -> float:
        alive = [r for r in self.replicas.values() if r.alive]
        if not alive:
            return float("inf")
        return sum(r.n_pending() for r in alive) / len(alive)

    def _scale_up(self) -> bool:
        mid = self.placement.acquire()
        if mid is None and self._spares:
            machine = self._spares.popleft()
            self.graph = self.graph.add_machine(machine)
            self.net.add_machine(self.graph)
            self.compute.add_machine(machine)
            mid = self.placement.on_machine_joined(machine, self.graph)
            # the join may be a strictly better entry node for some region:
            # the router re-derives its entry/score caches from the new graph
            self.router.on_machine_joined(
                self.graph, getattr(self.placement, "scores", None))
            self._rep_cache = None
            self.scale_log.append({"t": self.sim.now, "event": "join",
                                   "machine": mid, "region": machine.region})
        if mid is None:
            return False
        if mid in self.net.tombstoned:
            # re-provisioning a machine an earlier scale-down released
            self.net.revive_machine(mid)
            self.compute.revive_machine(mid)
            self._routing_changed()
            self.scale_log.append({"t": self.sim.now,
                                   "event": "machine_reprovisioned",
                                   "machine": mid})
        self._cold_start(mid)
        return True

    def _scale_down(self) -> bool:
        mid = self.placement.release()
        if mid is None:
            return False
        rep = self.replicas.pop(mid, None)
        if rep is None:
            if mid in self._provisioning:
                # released while its weights were still streaming: abort
                # the start (the machine already left placement.active, so
                # nothing goes orphaned)
                self._cancelled_starts.add(mid)
                return True
            return False
        self.retired.append(rep)
        self._routing_changed()
        self.scale_log.append({"t": self.sim.now, "event": "replica_down",
                               "machine": mid})
        drained = rep.drain()
        if self.resilience is not None:
            # only the drained (queued) attempts detach — in-flight sequences
            # finish on the draining replica and resolve normally
            for req in drained:
                for a in self._attempts.get(req.rid, []):
                    if a.done or a.rep is not rep or a.seq is None:
                        continue
                    a.done = True
                    if a.timeout_ev is not None:
                        a.timeout_ev.cancel()
                        a.timeout_ev = None
        for req in drained:
            self._dispatch(req)
        # release the machine once its in-flight sequences finish and their
        # responses have left: deprovisioned nodes stop relaying traffic
        rep.when_idle(lambda: self._deprovision(mid))
        return True

    def _deprovision(self, mid: int) -> None:
        if mid in self._provisioning \
                or (mid in self.replicas and self.replicas[mid].alive):
            return  # a scale-up re-hosted the machine while it drained
        self.net.remove_machine(mid)
        self.compute.remove_machine(mid)
        self._routing_changed()
        self.scale_log.append({"t": self.sim.now,
                               "event": "machine_deprovisioned",
                               "machine": mid})

    # -- faults --------------------------------------------------------------
    def _apply_fault(self, act) -> None:
        """Dispatch one compiled ``sim.faults.FaultAction``."""
        if self.obs.enabled:
            self.obs.metrics.inc("faults.injected")
            self.obs.metrics.inc(f"faults.{act.kind}")
            self.obs.trace.instant(
                "faults", act.kind, cat="fault",
                args={"injector": act.injector,
                      **{k: v for k, v in act.payload.items()
                         if isinstance(v, (int, float, str, bool))
                         and v is not None}})
        if act.kind == "crash":
            self._apply_crash(act.payload, act.injector)
        elif act.kind == "link":
            self.net.apply_link_fault(act.injector, act.payload["pairs"],
                                      bw_factor=act.payload["bw_factor"],
                                      lat_factor=act.payload["lat_factor"],
                                      cut=act.payload["cut"], sim=self.sim)
            self._routing_changed()
        elif act.kind == "link_clear":
            self.net.clear_link_fault(act.payload["fault_id"], sim=self.sim)
            self._routing_changed()
        elif act.kind == "gray":
            self.compute.set_gray(act.payload["machine"],
                                  act.payload["factor"])
        elif act.kind == "gray_clear":
            self.compute.set_gray(act.payload["machine"], 1.0)
        else:
            raise ValueError(f"unknown fault action {act.kind!r}")

    def _apply_crash(self, payload: dict, k: int) -> None:
        """Machines (or the replica processes on drawn victims) die.

        Explicit victims are *machine-level*: the node tombstones out of the
        network/compute models and stops relaying traffic. Drawn victims
        (``machines=()``) keep the legacy ``fault_fracs`` semantics — the
        replica process dies, the machine keeps routing — including the
        legacy rng key, which is what makes the shim bit-identical."""
        explicit = payload.get("machines", ())
        if explicit:
            victims = [int(v) for v in dict.fromkeys(explicit)
                       if int(v) < self.graph.n
                       and int(v) not in self.net.tombstoned]
            machine_level = True
        else:
            alive = sorted(m for m, r in self.replicas.items() if r.alive)
            if len(alive) <= 1:
                return
            rng = np.random.default_rng(
                (self.seed, faults_mod.CRASH_STREAM, k))
            kills = min(int(payload["kills"]), len(alive) - 1)
            victims = sorted(int(v) for v in
                             rng.choice(alive, size=kills, replace=False))
            machine_level = False
        if not victims:
            return
        if self.obs.enabled:
            # one instant per victim (the bulk crash instant's machine tuple
            # is filtered out of its args); trace analytics pairs these with
            # the per-machine "recover" instants into downtime intervals
            for v in victims:
                self.obs.trace.instant("faults", "machine_down", cat="fault",
                                       args={"machine": int(v),
                                             "machine_level": machine_level})
        interrupted = []
        hosted = set()
        for v in victims:
            rep = self.replicas.pop(v, None)
            if rep is not None:
                hosted.add(v)
                if self.resilience is not None:
                    self._detach_attempts(rep, record_failure=True)
                interrupted.extend(rep.fail())
                self.retired.append(rep)
                self.placement.on_machine_failed(v)
                self.scale_log.append({"t": self.sim.now,
                                       "event": "replica_failed",
                                       "machine": v})
            if machine_level:
                if v in self._provisioning:
                    # crash hit a cold start mid-stream: abort it
                    self._cancelled_starts.add(v)
                if v not in self.net.tombstoned:
                    self.net.remove_machine(v)
                    self.compute.remove_machine(v)
                self.scale_log.append({"t": self.sim.now,
                                       "event": "machine_crashed",
                                       "machine": v})
        self._routing_changed()
        for req in interrupted:
            self._dispatch(req)
        rec_after = payload.get("recover_after_s")
        if rec_after is not None and victims:
            self.sim.schedule(rec_after, self._apply_recover, tuple(victims),
                              machine_level, frozenset(hosted),
                              pin_epoch=False)

    def _apply_recover(self, victims, machine_level: bool, hosted) -> None:
        """Crashed machines come back: revive the tombstones, clear breaker
        history, and re-host a replica (nearest-peer cold start) on every
        machine that was hosting one when it died — unless the autoscaler
        already re-used the slot."""
        for v in victims:
            if v in self._provisioning or \
                    (v in self.replicas and self.replicas[v].alive):
                continue  # already re-provisioned through autoscaling
            if machine_level and v in self.net.tombstoned:
                self.net.revive_machine(v)
                self.compute.revive_machine(v)
            if self._breaker is not None:
                self._breaker.reset(v)
            self.scale_log.append({"t": self.sim.now,
                                   "event": "machine_recovered",
                                   "machine": v})
            if self.obs.enabled:
                self.obs.metrics.inc("faults.recoveries")
                self.obs.trace.instant("faults", "recover", cat="fault",
                                       args={"machine": int(v)})
            if v in hosted:
                self.placement.on_machine_recovered(v)
                self._cold_start(v)
        self._routing_changed()

    def _detach_attempts(self, rep, record_failure: bool = False) -> None:
        """Mark every live attempt admitted at ``rep`` done (its requests are
        about to be handed back via ``drain``/``fail`` and re-dispatched —
        without this they would resolve twice). Attempts whose prompt is
        still in flight stay live: ``_r_deliver`` re-dispatches those."""
        for atts in self._attempts.values():
            for a in atts:
                if a.done or a.rep is not rep or a.seq is None:
                    continue
                a.done = True
                if a.timeout_ev is not None:
                    a.timeout_ev.cancel()
                    a.timeout_ev = None
                if record_failure:
                    self._r_record_failure(rep.machine)

    # -- request flow --------------------------------------------------------
    def _on_arrival(self, req) -> None:
        if self.obs.enabled:
            self.obs.metrics.inc("serve.requests")
        if self.resilience is not None:
            self._r_arrival(req)
        else:
            self._route(req)

    def _dispatch(self, req) -> None:
        """Route (or re-route) through whichever request path is active."""
        if self.resilience is not None:
            self._r_dispatch(req)
        else:
            self._route(req)

    def _drop(self, rec, reason: str) -> None:
        if rec.dropped or rec.t_complete is not None:
            return
        rec.dropped = True
        rec.drop_reason = reason
        if self.resilience is not None:
            # no zombie work: outstanding attempts are cancelled with the drop
            for att in self._attempts.pop(rec.req.rid, []):
                if att.done:
                    continue
                att.done = True
                if att.timeout_ev is not None:
                    att.timeout_ev.cancel()
                    att.timeout_ev = None
                if att.seq is not None and att.rep.alive:
                    att.rep.abort(att.seq)
            self._pending_retry.pop(rec.req.rid, None)
        if self.obs.enabled:
            self.obs.metrics.inc("serve.dropped")
            self.obs.metrics.inc(f"serve.dropped.{reason}")
            self.obs.trace.instant("requests", "dropped", cat="request",
                                   args={"rid": rec.req.rid, "reason": reason,
                                         "n_routes": rec.n_routes})

    def _route(self, req) -> None:
        rec = self.records[req.rid]
        if rec.dropped or rec.t_complete is not None:
            return
        if rec.n_routes >= self.max_routes:
            self._drop(rec, "max_routes")
            return
        rep = self.router.pick(req, self._replica_list())
        if rep is None:
            self._drop(rec, "unreachable")
            return
        if rec.n_routes > 0 and self.obs.enabled:
            # failover edge: this request already ran (or queued) elsewhere
            self.obs.metrics.inc("serve.failovers")
            self.obs.trace.instant("requests", "failover", cat="request",
                                   args={"rid": req.rid,
                                         "to_machine": rep.machine,
                                         "attempt": rec.n_routes + 1})
        rec.n_routes += 1
        rec.machines.append(rep.machine)
        src = self.router.entry(req.region)
        nbytes = req.prompt_tokens * self.model.request_bytes_per_token
        self.net.transfer(self.sim, src, rep.machine, nbytes,
                          lambda: self._deliver(req, rep))

    def _deliver(self, req, rep) -> None:
        if not (rep.alive and rep.accepting):
            self._route(req)      # died/drained while the prompt was in flight
            return
        rep.submit(req, lambda seq, m=rep.machine: self._on_served(seq, m))

    def _on_served(self, seq, machine: int) -> None:
        req = seq.req
        dst = self.router.entry(req.region)
        if not self.net.reachable(machine, dst):
            # the response's only relay was deprovisioned mid-generation:
            # the reply is lost (the request path is guarded at pick time,
            # but a sequence admitted before the tombstone can finish after)
            self._drop(self.records[req.rid], "unreachable")
            return
        nbytes = req.gen_tokens * self.model.response_bytes_per_token
        self.net.transfer(self.sim, machine, dst,
                          nbytes, lambda: self._complete(req, seq))

    def _complete(self, req, seq) -> None:
        rec = self.records[req.rid]
        rec.t_complete = self.sim.now
        rec.latency_s = self.sim.now - req.t_arrival
        rec.t_first_token = seq.t_first_token
        if self.obs.enabled:
            m = self.obs.metrics
            m.inc("serve.completed")
            m.observe("serve.latency_s", rec.latency_s)
            if seq.t_first_token is not None:
                m.observe("serve.ttft_s", seq.t_first_token - req.t_arrival)
            # end-to-end request span on the fleet-wide requests lane
            # (replica-side queued/prefill/decode phases live on the
            # replica lanes — see serve.replica)
            self.obs.trace.async_span(
                "requests", "request", f"r{req.rid}", req.t_arrival,
                self.sim.now, cat="request",
                args={"rid": req.rid, "region": req.region,
                      "machines": list(rec.machines),
                      "n_routes": rec.n_routes,
                      "prompt_tokens": req.prompt_tokens,
                      "gen_tokens": req.gen_tokens})
        if self.autoscaler is not None and rec.latency_s is not None:
            self.autoscaler.observe_completion(rec.latency_s)

    # -- resilient request path (serve.resilience) ---------------------------
    # One request fans out into _Attempts. Liveness: every attempt either
    # completes, times out (retry policy), or dies with its replica (crash
    # handler / _r_deliver); retries are budget-bounded and every dispatch
    # consumes n_routes, so a request always terminates in _complete or in
    # _drop with a recorded reason — the invariant the chaos fuzzer checks.
    def _live_attempts(self, rid: int) -> list:
        return [a for a in self._attempts.get(rid, []) if not a.done]

    def _r_arrival(self, req) -> None:
        shed = self.resilience.shed
        if shed is not None and self._r_should_shed(req):
            if self.obs.enabled:
                self.obs.metrics.inc("serve.shed")
            self._drop(self.records[req.rid], "deadline")
            return
        self._r_dispatch(req)
        hp = self.resilience.hedge
        if hp is not None:
            self.sim.schedule(hp.delay_s, self._r_hedge, req,
                              pin_epoch=False)

    def _r_should_shed(self, req) -> bool:
        """Deadline-aware load shedding: drop on arrival if even the BEST
        replica's completion estimate (round-trip latency + backlog drain +
        zero-contention service time) blows the deadline. Estimates only —
        gray slowdowns and contention are invisible here, like real
        admission control working from advertised capacity."""
        pol = self.resilience.shed
        src = self.router.entry(req.region)
        best = math.inf
        for rep in self._replica_list():
            if not (rep.alive and rep.accepting and rep.fits(req)):
                continue
            if not self.net.reachable(src, rep.machine):
                continue
            lat = float(self.net.routed_ms[src, rep.machine]) * 1e-3
            est = 2.0 * lat + rep.est_wait_s() + self.model.service_s(
                req.prompt_tokens, req.gen_tokens,
                float(self.compute.tflops[rep.machine]))
            best = min(best, est)
        if not math.isfinite(best):
            return False    # nothing viable: let dispatch record unreachable
        return best > pol.deadline_s * pol.slack

    def _r_dispatch(self, req, hedge: bool = False) -> None:
        rec = self.records[req.rid]
        if rec.dropped or rec.t_complete is not None:
            return
        if rec.n_routes >= self.max_routes:
            if not hedge and not self._live_attempts(req.rid) \
                    and self._pending_retry.get(req.rid, 0) == 0:
                self._drop(rec, "max_routes")
            return
        exclude = tuple(a.rep.machine for a in self._live_attempts(req.rid)) \
            if hedge else ()
        rep = self.router.pick(req, self._replica_list(), exclude=exclude,
                               breaker=self._breaker, now=self.sim.now)
        if rep is None:
            if not hedge and not self._live_attempts(req.rid) \
                    and self._pending_retry.get(req.rid, 0) == 0:
                self._drop(rec, "unreachable")
            return
        if rec.n_routes > 0 and self.obs.enabled:
            self.obs.metrics.inc("serve.failovers")
            self.obs.trace.instant("requests", "failover", cat="request",
                                   args={"rid": req.rid,
                                         "to_machine": rep.machine,
                                         "attempt": rec.n_routes + 1})
        rec.n_routes += 1
        rec.machines.append(rep.machine)
        att = _Attempt(rep=rep, t_start=self.sim.now, hedged=hedge)
        self._attempts.setdefault(req.rid, []).append(att)
        if hedge:
            rec.hedges += 1
            if self.obs.enabled:
                self.obs.metrics.inc("serve.hedges")
                self.obs.trace.instant("requests", "hedge", cat="request",
                                       args={"rid": req.rid,
                                             "to_machine": rep.machine})
        pol = self.resilience.retry
        if pol is not None:
            att.timeout_ev = self.sim.schedule(pol.timeout_s,
                                               self._r_timeout, req, att,
                                               pin_epoch=False)
        src = self.router.entry(req.region)
        nbytes = req.prompt_tokens * self.model.request_bytes_per_token
        self.net.transfer(self.sim, src, rep.machine, nbytes,
                          lambda: self._r_deliver(req, att))

    def _r_deliver(self, req, att) -> None:
        if att.done:
            return
        rec = self.records[req.rid]
        if rec.dropped or rec.t_complete is not None:
            att.done = True
            return
        rep = att.rep
        if not (rep.alive and rep.accepting):
            # replica died/drained while the prompt was in flight
            att.done = True
            if att.timeout_ev is not None:
                att.timeout_ev.cancel()
                att.timeout_ev = None
            self._r_record_failure(rep.machine)
            self._r_dispatch(req)
            return
        att.seq = rep.submit(req, lambda seq, a=att: self._r_served(req, a))

    def _r_timeout(self, req, att) -> None:
        att.timeout_ev = None
        if att.done:
            return
        rec = self.records[req.rid]
        if rec.dropped or rec.t_complete is not None:
            return
        att.done = True
        if att.seq is not None and att.rep.alive:
            att.rep.abort(att.seq)
        self._r_record_failure(att.rep.machine)
        if self.obs.enabled:
            self.obs.metrics.inc("serve.attempt_timeouts")
        pol = self.resilience.retry
        if rec.retries >= pol.max_retries:
            if not self._live_attempts(req.rid) \
                    and self._pending_retry.get(req.rid, 0) == 0:
                self._drop(rec, "retry_budget")
            return
        rec.retries += 1
        if self.obs.enabled:
            self.obs.metrics.inc("serve.retries")
        backoff = pol.backoff_base_s * pol.backoff_mult ** (rec.retries - 1)
        self._pending_retry[req.rid] = \
            self._pending_retry.get(req.rid, 0) + 1
        self.sim.schedule(backoff, self._r_retry_fire, req, pin_epoch=False)

    def _r_retry_fire(self, req) -> None:
        left = self._pending_retry.get(req.rid, 0) - 1
        if left > 0:
            self._pending_retry[req.rid] = left
        else:
            self._pending_retry.pop(req.rid, None)
        rec = self.records[req.rid]
        if rec.dropped or rec.t_complete is not None:
            return
        self._r_dispatch(req)

    def _r_served(self, req, att) -> None:
        if att.done:
            return
        att.done = True
        if att.timeout_ev is not None:
            att.timeout_ev.cancel()
            att.timeout_ev = None
        rec = self.records[req.rid]
        if rec.dropped or rec.t_complete is not None:
            return
        dst = self.router.entry(req.region)
        if not self.net.reachable(att.rep.machine, dst):
            # response lost (see _on_served); resilient mode re-dispatches
            # instead of dropping — the work is gone, the request is not
            self._r_record_failure(att.rep.machine)
            self._r_dispatch(req)
            return
        self._r_record_success(att.rep.machine)
        nbytes = req.gen_tokens * self.model.response_bytes_per_token
        self.net.transfer(self.sim, att.rep.machine, dst, nbytes,
                          lambda: self._r_complete(req, att))

    def _r_complete(self, req, att) -> None:
        rec = self.records[req.rid]
        if rec.dropped or rec.t_complete is not None:
            return      # a faster attempt already resolved this request
        if att.hedged and self.obs.enabled:
            self.obs.metrics.inc("serve.hedge_wins")
        # first completion wins: cancel the losers at their replicas
        for other in self._attempts.pop(req.rid, []):
            if other is att or other.done:
                continue
            other.done = True
            if other.timeout_ev is not None:
                other.timeout_ev.cancel()
                other.timeout_ev = None
            if other.seq is not None and other.rep.alive:
                other.rep.abort(other.seq)
        self._pending_retry.pop(req.rid, None)
        self._complete(req, att.seq)

    def _r_hedge(self, req) -> None:
        rec = self.records[req.rid]
        if rec.dropped or rec.t_complete is not None:
            return
        hp = self.resilience.hedge
        if rec.hedges >= hp.max_hedges:
            return
        if self._live_attempts(req.rid):
            self._r_dispatch(req, hedge=True)
        if rec.hedges < hp.max_hedges and not rec.dropped \
                and rec.t_complete is None:
            self.sim.schedule(hp.delay_s, self._r_hedge, req,
                              pin_epoch=False)

    def _r_record_failure(self, machine: int) -> None:
        if self._breaker is None:
            return
        if self.obs.enabled:
            self.obs.metrics.inc("serve.breaker_failures")
        if self._breaker.record_failure(machine, self.sim.now):
            if self.obs.enabled:
                self.obs.metrics.inc("serve.breaker_ejections")
                self.obs.trace.instant("requests", "breaker_open",
                                       cat="serve",
                                       args={"machine": int(machine)})

    def _r_record_success(self, machine: int) -> None:
        if self._breaker is not None:
            self._breaker.record_success(machine)

    # -- entry point ---------------------------------------------------------
    def start(self) -> None:
        """Schedule arrivals, the fault plan and the autoscaler — everything
        ``run()`` does before draining the heap. Split out so a colocated
        host can start several tenants on one shared ``Simulator``."""
        for req in self.trace:
            self.sim.schedule(req.t_arrival, self._on_arrival, req,
                              pin_epoch=False)
        if self.fault_plan is not None:
            for act in faults_mod.compile_plan(self.fault_plan, self.graph,
                                               max(self.horizon, 1.0),
                                               self.seed):
                self.sim.schedule(act.t, self._apply_fault, act,
                                  pin_epoch=False)
        if self.autoscaler is not None:
            self.autoscaler.start()

    def run(self) -> dict:
        self.start()
        self.sim.run(until=self.run_until)
        return self.collect()

    def collect(self) -> dict:
        if self.autoscaler is not None:
            self.autoscaler.stop()
        all_reps = list(self.replicas.values()) + self.retired
        # metrics snapshot: the cheap core counters always; the full obs
        # registry (flattened) when a recorder was attached
        metrics = {
            "engine.events_dispatched": self.sim.events_dispatched,
            "engine.events_scheduled": self.sim.events_scheduled,
            "net.solver.solves": self.net.n_solves,
            "net.bytes_moved": float(self.net.bytes_moved),
        }
        if self.obs.enabled:
            metrics.update(self.obs.metrics.flat())
        return {
            "policy": self.policy,
            "records": self.records,
            "horizon_s": self.horizon,
            "end_s": self.sim.now,
            "n_events": self.sim.events_dispatched,
            "bytes_moved": self.net.bytes_moved,
            "metrics": metrics,
            "replicas": [r.stats() for r in all_reps],
            "scale_log": list(self.scale_log),
            "autoscale_log": (list(self.autoscaler.log)
                              if self.autoscaler else []),
            "final_replicas": sorted(m for m, r in self.replicas.items()
                                     if r.alive),
        }
