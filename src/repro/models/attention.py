"""Attention layers: GQA (with sliding window, qk-norm) and DeepSeek-style MLA.

Two execution paths per layer:
  * full-sequence (train / prefill) — optionally routed through the Pallas
    flash-attention kernel (FLAGS["use_flash"], TPU target);
  * single-token decode against a KV cache — full cache, ring (sliding-window)
    cache, or MLA compressed cache (plain or absorbed matmul order).

Shapes: x (B, S, d_model); caches live in a dict pytree so they pjit-shard
with NamedSharding like any other state.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttnSpec, MLASpec
from repro.models import common as cc
from repro.models.common import (apply_norm, apply_rope, causal_mask,
                                 dense_init, logical_constraint)

from repro.models.common import RUNTIME as FLAGS  # launcher-set knobs


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def init_attn(key, spec: AttnSpec, d_model: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    h, kv, dh = spec.n_heads, spec.n_kv_heads, spec.head_dim
    p = {
        "wq": dense_init(ks[0], d_model, h * dh, dtype),
        "wk": dense_init(ks[1], d_model, kv * dh, dtype),
        "wv": dense_init(ks[2], d_model, kv * dh, dtype),
        "wo": dense_init(ks[3], h * dh, d_model, dtype),
    }
    if spec.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((dh,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((dh,), jnp.float32)}
    return p


def _project_qkv(p, spec: AttnSpec, x, positions):
    b, s, _ = x.shape
    h, kv, dh = spec.n_heads, spec.n_kv_heads, spec.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, kv, dh)
    v = (x @ p["wv"]).reshape(b, s, kv, dh)
    if spec.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    if spec.use_rope:
        q = apply_rope(q, positions, spec.rope_theta)
        k = apply_rope(k, positions, spec.rope_theta)
    return q, k, v


def _gqa_attend(q, k, v, mask):
    """q: (B,S,H,D) k/v: (B,T,KV,D); grouped einsum, no KV repetition."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, dh)
    scores = jnp.einsum("bskgd,btkd->bksgt", qg, k).astype(jnp.float32)
    scores *= dh ** -0.5
    scores = jnp.where(mask[:, None, :, None, :] if mask.ndim == 3
                       else mask[None, None, :, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bksgt,btkd->bskgd", w, v)
    return out.reshape(b, s, h, dh)


def _chunked_attend(q, k, v, spec: AttnSpec, q_chunk: int):
    """Flash-style q-block attention in pure XLA — the shardable form for
    SPMD lowering: q keeps full heads (shardable on `model` even when
    n_kv_heads < axis size), kv heads are repeated *after* sharding
    propagation (a per-shard slice, not a materialized copy), and the
    (bq, T) score tile is the only quadratic live tensor. The chunk body is
    rematerialized so backward residuals stay one tile big.

    q: (B, S, H, D); k/v: (B, T, KV, D). S % q_chunk == 0 (callers pad)."""
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    kr = jnp.repeat(k, g, axis=2)                   # (B, T, H, D)
    vr = jnp.repeat(v, g, axis=2)
    nq = s // q_chunk
    qb = q.reshape(b, nq, q_chunk, h, dh).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(t, dtype=jnp.int32)

    def body(idx_qblk):
        idx, q_blk = idx_qblk                       # q_blk (B, bq, H, D)
        qpos = idx * q_chunk + jnp.arange(q_chunk, dtype=jnp.int32)
        scores = jnp.einsum("bqhd,bthd->bhqt", q_blk, kr,
                            preferred_element_type=jnp.float32)
        scores = scores * dh ** -0.5
        if spec.causal:
            m = kpos[None, :] <= qpos[:, None]
            if spec.window is not None:
                m &= kpos[None, :] > (qpos[:, None] - spec.window)
            scores = jnp.where(m[None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqt,bthd->bqhd", w, vr)

    out = jax.lax.map(jax.checkpoint(body),
                      (jnp.arange(nq, dtype=jnp.int32), qb))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)


def attn_full(p, spec: AttnSpec, x, positions, return_kv: bool = False):
    """Training / prefill self-attention (causal unless spec.causal=False)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, spec, x, positions)
    q = logical_constraint(q, cc.BATCH, None, cc.HEADS, None)
    k = logical_constraint(k, cc.BATCH, None, cc.HEADS, None)
    v = logical_constraint(v, cc.BATCH, None, cc.HEADS, None)
    q_chunk = FLAGS["q_chunk"]
    if FLAGS["use_flash"] and spec.causal:
        from repro.kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(q, k, v, window=spec.window)
    elif q_chunk and s % q_chunk == 0 and s > q_chunk:
        out = _chunked_attend(q, k, v, spec, q_chunk)
    else:
        if spec.causal:
            mask = causal_mask(positions, positions, spec.window)
        else:
            mask = jnp.ones((s, s), bool) if positions.ndim == 1 else \
                jnp.ones((b, s, s), bool)
        out = _gqa_attend(q, k, v, mask)
    y = out.reshape(b, s, -1) @ p["wo"]
    y = logical_constraint(y, cc.BATCH, cc.SEQ, cc.EMBED)
    if return_kv:
        return y, (k, v)
    return y


def attn_cross(p, spec: AttnSpec, x, kv_cache: tuple):
    """Cross-attention (whisper decoder): K,V precomputed from the encoder."""
    b, s, _ = x.shape
    h, dh = spec.n_heads, spec.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k, v = kv_cache
    mask = jnp.ones((b, s, k.shape[1]), bool)
    out = _gqa_attend(q, k, v, mask)
    return out.reshape(b, s, -1) @ p["wo"]


# -- KV caches ---------------------------------------------------------------
def cache_len(spec: AttnSpec, max_len: int) -> int:
    return max_len if spec.window is None else min(spec.window, max_len)


def init_cache(spec: AttnSpec, batch: int, max_len: int, dtype) -> dict:
    """Full cache, or ring cache bounded at the sliding window."""
    t = max_len if spec.window is None else min(spec.window, max_len)
    kv, dh = spec.n_kv_heads, spec.head_dim
    cache = {
        "k": jnp.zeros((batch, t, kv, dh), dtype),
        "v": jnp.zeros((batch, t, kv, dh), dtype),
    }
    if spec.window is not None:
        # per-slot absolute positions (-1 = empty)
        cache["slot_pos"] = jnp.full((t,), -1, jnp.int32)
    return cache


def attn_prefill(p, spec: AttnSpec, x, positions, max_len: int):
    """Full forward that also fills the decode cache. Assumes positions are
    0..S-1 (no padding). Returns (y, cache)."""
    b, s, _ = x.shape
    y, (k, v) = attn_full(p, spec, x, positions, return_kv=True)
    t = cache_len(spec, max_len)
    if spec.window is None:
        cache = init_cache(spec, b, max_len, x.dtype)
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
    else:
        # last min(S, W) tokens land in their ring slots
        w = t
        take = min(s, w)
        idx = jnp.arange(s - take, s, dtype=jnp.int32)       # absolute positions
        slots = jnp.mod(idx, w)
        kk = jnp.zeros((b, w) + k.shape[2:], x.dtype).at[:, slots].set(
            k[:, s - take:])
        vv = jnp.zeros((b, w) + v.shape[2:], x.dtype).at[:, slots].set(
            v[:, s - take:])
        slot_pos = jnp.full((w,), -1, jnp.int32).at[slots].set(idx)
        cache = {"k": kk, "v": vv, "slot_pos": slot_pos}
    return y, cache


def attn_decode(p, spec: AttnSpec, x, pos, cache: dict):
    """One-token decode. x: (B,1,d); pos: scalar int32 (current position).
    Returns (y, new_cache)."""
    b = x.shape[0]
    h, kvh, dh = spec.n_heads, spec.n_kv_heads, spec.head_dim
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, spec, x, positions)

    t = cache["k"].shape[1]
    if spec.window is None:
        slot = pos
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
        k_pos = jnp.arange(t, dtype=jnp.int32)
        valid = k_pos <= pos
        new_cache = {"k": k, "v": v}
    else:
        slot = jnp.mod(pos, t)
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
        slot_pos = jax.lax.dynamic_update_slice(
            cache["slot_pos"], jnp.full((1,), pos, jnp.int32), (slot,))
        valid = (slot_pos >= 0) & (slot_pos <= pos) & (slot_pos > pos - spec.window)
        k_pos = slot_pos
        new_cache = {"k": k, "v": v, "slot_pos": slot_pos}

    if FLAGS["use_flash"]:
        from repro.kernels.decode_attention import ops as dec_ops
        out = dec_ops.decode_attention(q, k, v, valid)
    else:
        mask = valid[None, None, :]  # (1,1,T) broadcast over batch, q=1
        out = _gqa_attend(q, k, v, mask)
    y = out.reshape(b, 1, -1) @ p["wo"]
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank Q and compressed KV with decoupled RoPE.
# ---------------------------------------------------------------------------
def init_mla(key, spec: MLASpec, d_model: int, dtype) -> dict:
    ks = jax.random.split(key, 5)
    h = spec.n_heads
    qd = spec.qk_nope_dim + spec.qk_rope_dim
    return {
        "wq_a": dense_init(ks[0], d_model, spec.q_lora_rank, dtype),
        "q_norm": {"scale": jnp.ones((spec.q_lora_rank,), jnp.float32)},
        "wq_b": dense_init(ks[1], spec.q_lora_rank, h * qd, dtype),
        "wkv_a": dense_init(ks[2], d_model,
                            spec.kv_lora_rank + spec.qk_rope_dim, dtype),
        "kv_norm": {"scale": jnp.ones((spec.kv_lora_rank,), jnp.float32)},
        "wkv_b": dense_init(ks[3], spec.kv_lora_rank,
                            h * (spec.qk_nope_dim + spec.v_head_dim), dtype),
        "wo": dense_init(ks[4], h * spec.v_head_dim, d_model, dtype),
    }


def _mla_q(p, spec: MLASpec, x, positions):
    b, s, _ = x.shape
    h = spec.n_heads
    q = apply_norm(p["q_norm"], x @ p["wq_a"], "rmsnorm") @ p["wq_b"]
    q = q.reshape(b, s, h, spec.qk_nope_dim + spec.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [spec.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, spec.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p, spec: MLASpec, x, positions):
    """Returns (normalized compressed kv, rotated shared k_rope)."""
    kv_a = x @ p["wkv_a"]
    c_kv, k_rope = jnp.split(kv_a, [spec.kv_lora_rank], axis=-1)
    c_kv = apply_norm(p["kv_norm"], c_kv, "rmsnorm")          # (B,S,L)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        spec.rope_theta)[:, :, 0, :]          # (B,S,R)
    return c_kv, k_rope


def _mla_chunked(q_nope, q_rope, k_nope, k_rope, v, scale, q_chunk: int,
                 dtype):
    """q-block chunked MLA attention (same memory argument as
    _chunked_attend; k_rope is shared across heads so it never repeats)."""
    b, s, h, dn = q_nope.shape
    t = k_nope.shape[1]
    nq = s // q_chunk
    qn = q_nope.reshape(b, nq, q_chunk, h, dn).transpose(1, 0, 2, 3, 4)
    qr = q_rope.reshape(b, nq, q_chunk, h, -1).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(t, dtype=jnp.int32)

    def body(args):
        idx, qn_blk, qr_blk = args
        qpos = idx * q_chunk + jnp.arange(q_chunk, dtype=jnp.int32)
        scores = (jnp.einsum("bqhn,bthn->bhqt", qn_blk, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bqhr,btr->bhqt", qr_blk, k_rope,
                               preferred_element_type=jnp.float32)) * scale
        m = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(m[None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(dtype)
        return jnp.einsum("bhqt,bthd->bqhd", w, v)

    out = jax.lax.map(jax.checkpoint(body),
                      (jnp.arange(nq, dtype=jnp.int32), qn, qr))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, -1)


def mla_full(p, spec: MLASpec, x, positions):
    b, s, _ = x.shape
    h = spec.n_heads
    q_nope, q_rope = _mla_q(p, spec, x, positions)
    c_kv, k_rope = _mla_ckv(p, spec, x, positions)
    kv = (c_kv @ p["wkv_b"]).reshape(b, s, h, spec.qk_nope_dim + spec.v_head_dim)
    k_nope, v = jnp.split(kv, [spec.qk_nope_dim], axis=-1)
    k_nope = logical_constraint(k_nope, cc.BATCH, None, cc.HEADS, None)
    v = logical_constraint(v, cc.BATCH, None, cc.HEADS, None)
    scale = (spec.qk_nope_dim + spec.qk_rope_dim) ** -0.5
    q_chunk = FLAGS["q_chunk"]
    if q_chunk and s % q_chunk == 0 and s > q_chunk:
        out = _mla_chunked(q_nope, q_rope, k_nope, k_rope, v, scale, q_chunk,
                           x.dtype)
        return out @ p["wo"]
    scores = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
              + jnp.einsum("bshr,btr->bhst", q_rope, k_rope)).astype(jnp.float32)
    scores *= scale
    mask = causal_mask(positions, positions)
    scores = jnp.where(mask[:, None] if mask.ndim == 3 else mask[None, None],
                       scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", w, v).reshape(b, s, -1)
    return out @ p["wo"]


def init_mla_cache(spec: MLASpec, batch: int, max_len: int, dtype) -> dict:
    """The MLA win: cache only (kv_lora_rank + rope_dim) per token."""
    return {
        "ckv": jnp.zeros((batch, max_len, spec.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, spec.qk_rope_dim), dtype),
    }


def mla_prefill(p, spec: MLASpec, x, positions, max_len: int):
    b, s, _ = x.shape
    y = mla_full(p, spec, x, positions)
    c_kv, k_rope = _mla_ckv(p, spec, x, positions)
    cache = init_mla_cache(spec, b, max_len, x.dtype)
    cache["ckv"] = jax.lax.dynamic_update_slice(
        cache["ckv"], c_kv.astype(x.dtype), (0, 0, 0))
    cache["k_rope"] = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope.astype(x.dtype), (0, 0, 0))
    return y, cache


def mla_decode(p, spec: MLASpec, x, pos, cache: dict, absorb: bool = False):
    """One-token MLA decode. absorb=True uses the matmul-absorbed order
    (never re-expands K/V for the whole cache — the §Perf variant)."""
    b = x.shape[0]
    h = spec.n_heads
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, spec, x, positions)            # (B,1,H,*)
    c_new, r_new = _mla_ckv(p, spec, x, positions)            # (B,1,L),(B,1,R)
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_new, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], r_new, (0, pos, 0))
    new_cache = {"ckv": ckv, "k_rope": k_rope}

    t = ckv.shape[1]
    valid = jnp.arange(t, dtype=jnp.int32) <= pos
    scale = (spec.qk_nope_dim + spec.qk_rope_dim) ** -0.5
    wkv_b = p["wkv_b"].reshape(spec.kv_lora_rank, h,
                               spec.qk_nope_dim + spec.v_head_dim)
    w_k = wkv_b[..., :spec.qk_nope_dim]    # (L,H,N)
    w_v = wkv_b[..., spec.qk_nope_dim:]    # (L,H,V)

    if absorb:
        q_eff = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_k)     # (B,1,H,L)
        scores = (jnp.einsum("bqhl,btl->bhqt", q_eff, ckv)
                  + jnp.einsum("bqhr,btr->bhqt", q_rope, k_rope))
    else:
        kv = (ckv @ p["wkv_b"]).reshape(b, t, h,
                                        spec.qk_nope_dim + spec.v_head_dim)
        k_nope, v_full = jnp.split(kv, [spec.qk_nope_dim], axis=-1)
        scores = (jnp.einsum("bqhn,bthn->bhqt", q_nope, k_nope)
                  + jnp.einsum("bqhr,btr->bhqt", q_rope, k_rope))
    scores = scores.astype(jnp.float32) * scale
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)

    if absorb:
        ctx = jnp.einsum("bhqt,btl->bqhl", w, ckv)            # (B,1,H,L)
        out = jnp.einsum("bqhl,lhv->bqhv", ctx, w_v)
    else:
        out = jnp.einsum("bhqt,bthv->bqhv", w, v_full)
    y = out.reshape(b, 1, -1) @ p["wo"]
    return y, new_cache
