"""Sharding-rule tests: divisibility-awareness over real arch param shapes
(ShapeDtypeStruct trees — no allocation), using AbstractMesh so the 16x16
production mesh needs no real devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS, get_config, reduce_for_smoke
from repro.models.registry import get_api
from repro.parallel.sharding import (DEFAULT_ACT_RULES, ShardingRules,
                                     _fit_axes, param_specs)

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _param_structs(arch):
    cfg = get_config(arch)
    api = get_api(cfg)
    return cfg, jax.eval_shape(lambda k: api.init_params(cfg, k),
                               jax.random.PRNGKey(0))


def _check_divisibility(tree, specs, mesh):
    def ok(leaf, spec):
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (leaf.shape, spec)

    jax.tree.map(ok, tree, specs,
                 is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ["gemma3-1b", "qwen3-32b", "olmoe-1b-7b",
                                  "deepseek-v2-236b", "jamba-1.5-large-398b"])
def test_param_specs_divisible(arch):
    cfg, structs = _param_structs(arch)
    rules = ShardingRules(mesh=MESH)
    specs = param_specs(rules, structs)
    _check_divisibility(structs, specs, MESH)


def test_param_specs_multipod_divisible():
    cfg, structs = _param_structs("qwen3-32b")
    rules = ShardingRules(mesh=MESH3)
    specs = param_specs(rules, structs)
    _check_divisibility(structs, specs, MESH3)


def test_gemma3_heads_drop_tp():
    """gemma3 has 4 heads — model=16 TP cannot shard wq's output
    (4 heads x 256 = 1024 dim IS divisible by 16 though: rule applies to the
    fused dim). The guarantee under test is divisibility, not head count."""
    cfg, structs = _param_structs("gemma3-1b")
    rules = ShardingRules(mesh=MESH)
    specs = param_specs(rules, structs)
    _check_divisibility(structs, specs, MESH)


def test_fit_axes_drops_nondivisible():
    assert _fit_axes(4, ("model",), MESH, set()) == ()          # 4 % 16 != 0
    assert _fit_axes(64, ("model",), MESH, set()) == ("model",)
    assert _fit_axes(32, ("pod", "data"), MESH3, set()) == ("pod", "data")
    assert _fit_axes(2, ("pod", "data"), MESH3, set()) == ("pod",)
    assert _fit_axes(1, ("pod", "data"), MESH3, set()) == ()


def test_moe_experts_on_model_axis():
    cfg, structs = _param_structs("olmoe-1b-7b")
    rules = ShardingRules(mesh=MESH)
    specs = param_specs(rules, structs)
    # find a stacked moe w_up leaf: (count, E, D, F) -> spec (None, model, ...)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    moe_specs = [(p, s) for p, s in flat
                 if "moe" in "/".join(str(getattr(q, "key", "")) for q in p)
                 and "w_up" in str(p[-1])]
    assert moe_specs, "no moe leaves found"
    for path, spec in moe_specs:
        assert "model" in jax.tree.leaves(tuple(spec)), spec


def test_norms_replicated():
    cfg, structs = _param_structs("qwen3-32b")
    specs = param_specs(ShardingRules(mesh=MESH), structs)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    for path, spec in flat:
        names = "/".join(str(getattr(q, "key", "")) for q in path)
        if "norm" in names:
            assert all(s is None for s in spec), (names, spec)
