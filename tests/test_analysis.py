"""repro.obs.analysis: attribution, critical path, waterfalls, trace diff.

Pins the tentpole contracts: per lane, the five attribution buckets sum to
the run window *exactly* (integer µs — beats the 1 µs acceptance bound with
zero error); overlapping async spans are unioned, never double-counted; the
analysis is a pure function of the trace document, so same-seed runs yield
byte-identical attribution JSON; the critical path explains >= 95% of a
sequential training run's makespan; per-request waterfall phases sum to the
recorded end-to-end latency exactly; ring-truncated traces keep every
invariant over the surviving window.
"""
import json

import numpy as np
import pytest

from repro import obs
from repro.core import cost_model as cm
from repro.core.graph import ClusterGraph, Machine
from repro.obs import analysis, report, schema
from repro.obs.analysis import (clip_intervals, merge_intervals,
                                subtract_intervals, total_us)
from repro.obs.trace import Tracer
from repro.serve import TrafficConfig, ModelMix, generate, \
    serve_model_from_task

CHAT = serve_model_from_task(cm.ModelTask("Chat-34B", 34e9, 60, 7168),
                             name="chat-34b", decode_efficiency=0.01)
MIX = (ModelMix("chat-34b", prompt_median=64.0, gen_median=24.0),)


def _star_graph():
    machines = [Machine.from_caps("London", capability=7.0, memory_gb=32.0,
                                  tflops=500.0, label="edge"),
                Machine("Paris", "A100", 8), Machine("Tokyo", "A100", 8)]
    lat = np.array([[0, 10, 200], [10, 0, 210], [200, 210, 0]], np.float32)
    return ClusterGraph(machines, lat)


def _serve_doc(data_plane="fast", seed=0, traffic_seed=2):
    from repro.sim import ServeExecutor
    g = _star_graph()
    trace = generate(TrafficConfig(rate_rps=4.0, horizon_s=40.0,
                                   regions=("London",), mixes=MIX),
                     seed=traffic_seed)
    rec = obs.Recorder()
    ServeExecutor(g, CHAT, trace, "least_loaded", n_replicas=2,
                  fault_fracs=(0.5,), seed=seed, data_plane=data_plane,
                  obs=rec).run()
    return schema.validate_bytes(rec.trace.json_bytes())


def _train_doc(scenario="straggler_heavy", seed=0):
    from repro.sim import scenarios as sc
    from repro.sim.evaluate import FleetSimulation, FullFleetPlacer
    scn = sc.get_scenario(scenario)
    graph = scn.fleet(seed)
    tasks = list(scn.tasks)
    rec = obs.Recorder()
    fs = FleetSimulation(graph, tasks,
                         FullFleetPlacer("gpipe", tasks, "B"),
                         comm_model=scn.comm_model, jitter=scn.jitter,
                         traffic=scn.traffic, fault_fracs=scn.fault_fracs,
                         kills_per_fault=scn.kills_per_fault,
                         steps=scn.steps, seed=seed, concurrent=False,
                         obs=rec)
    with obs.recording(rec):
        fs.run()
    return schema.validate_bytes(rec.trace.json_bytes())


@pytest.fixture(scope="module")
def serve_doc():
    return _serve_doc()


@pytest.fixture(scope="module")
def train_doc():
    return _train_doc()


# ---------------------------------------------------------------------------
# Interval algebra
# ---------------------------------------------------------------------------
def test_interval_algebra_exact():
    ivs = [(5, 10), (0, 3), (9, 12), (12, 12), (20, 25)]
    merged = merge_intervals(ivs)
    assert merged == [(0, 3), (5, 12), (20, 25)]   # overlap + touch unioned
    assert total_us(merged) == 3 + 7 + 5
    assert subtract_intervals(merged, [(6, 21)]) == [(0, 3), (5, 6), (21, 25)]
    assert subtract_intervals(merged, []) == merged
    assert subtract_intervals(merged, merged) == []
    assert clip_intervals(merged, 2, 22) == [(2, 3), (5, 12), (20, 22)]
    assert clip_intervals(merged, 100, 200) == []


def test_subtract_covers_partial_and_full_overlap():
    a = [(0, 100)]
    b = [(10, 20), (20, 30), (90, 150)]
    assert subtract_intervals(a, merge_intervals(b)) == [(0, 10), (30, 90)]
    assert subtract_intervals([(10, 20)], [(0, 100)]) == []


# ---------------------------------------------------------------------------
# Attribution on synthetic traces
# ---------------------------------------------------------------------------
def _sum_ok(att):
    return [lane for lane, b in att.lanes.items()
            if sum(b.values()) != att.wall_us]


def test_overlapping_async_spans_counted_once():
    tr = Tracer()
    # two concurrent outbound flows overlap on machine/0: union is 15s,
    # the naive sum would be 20s
    tr.async_span("machine/0", "xfer->1", "f1", 0.0, 10.0, cat="net")
    tr.async_span("machine/0", "xfer->2", "f2", 5.0, 15.0, cat="net")
    tr.async_span("machine/0", "xfer->1", "f3", 20.0, 25.0, cat="net")
    att = analysis.attribute(tr.to_chrome())
    b = att.lanes["machine/0"]
    assert b["comm"] == 20_000_000          # (0,15) + (20,25), not 25s
    assert b["idle"] == 5_000_000           # (15,20)
    assert _sum_ok(att) == []


def test_zero_duration_spans_do_not_break_sums():
    tr = Tracer()
    tr.span_at("replica/1", "prefill", 1.0, 1.0)       # zero-duration
    tr.async_span("replica/1", "decode", "s1", 1.0, 1.0)
    tr.span_at("replica/1", "decode", 1.0, 3.0)
    att = analysis.attribute(tr.to_chrome())
    b = att.lanes["replica/1"]
    assert b["compute"] == 2_000_000
    assert _sum_ok(att) == []


def test_queue_overlapping_compute_yields_to_compute():
    # precedence compute > queue: a replica queueing one sequence while
    # decoding another charges the overlap to compute (resource view);
    # request-centric queueing lives in the waterfalls instead
    tr = Tracer()
    tr.async_span("replica/0", "decode", "a", 0.0, 10.0)
    tr.async_span("replica/0", "queued", "b", 2.0, 12.0)
    att = analysis.attribute(tr.to_chrome())
    b = att.lanes["replica/0"]
    assert b["compute"] == 10_000_000
    assert b["queue"] == 2_000_000          # only the non-overlapped tail
    assert _sum_ok(att) == []


def test_step_span_splits_into_compute_then_comm():
    tr = Tracer()
    tr.span_at("task/T", "step0", 0.0, 10.0, cat="step",
               args={"compute_s": 6.0, "comm_s": 4.0})
    tr.span_at("task/T", "step1", 10.0, 12.0, cat="step")  # no args: compute
    att = analysis.attribute(tr.to_chrome())
    b = att.lanes["task/T"]
    assert b["compute"] == 8_000_000 and b["comm"] == 4_000_000
    assert _sum_ok(att) == []


def test_fault_recovery_from_downtime_instants():
    tr = Tracer()
    tr.async_span("machine/1", "xfer->0", "f", 0.0, 5.0, cat="net")
    tr.span_at("replica/1", "decode", 0.0, 5.0)
    tr.instant("faults", "machine_down", cat="fault", args={"machine": 1})
    # instants stamp at now()=0; re-stamp via clock to place them in time
    tr.now = lambda: 10.0
    tr.instant("faults", "machine_down", cat="fault", args={"machine": 1})
    tr.now = lambda: 20.0
    tr.instant("faults", "recover", cat="fault", args={"machine": 1})
    tr.now = lambda: 30.0
    tr.instant("faults", "done", cat="fault")
    att = analysis.attribute(tr.to_chrome())
    # the first machine_down (t=0) opened the interval; duplicate down
    # instants are ignored, recover at t=20 closes it, but t in [0,5) is
    # already claimed by comm/compute (precedence)
    assert att.lanes["machine/1"]["fault_recovery"] == 15_000_000
    assert att.lanes["replica/1"]["fault_recovery"] == 15_000_000
    assert _sum_ok(att) == []


def test_process_level_crash_downs_replica_not_machine():
    tr = Tracer()
    tr.async_span("machine/2", "xfer->0", "f", 0.0, 2.0, cat="net")
    tr.span_at("replica/2", "decode", 0.0, 2.0)
    tr.now = lambda: 4.0
    tr.instant("faults", "machine_down", cat="fault",
               args={"machine": 2, "machine_level": False})
    tr.now = lambda: 10.0
    tr.instant("faults", "done", cat="fault")
    att = analysis.attribute(tr.to_chrome())
    # replica process died (down till window end); the machine keeps routing
    assert att.lanes["replica/2"]["fault_recovery"] == 6_000_000
    assert att.lanes["machine/2"]["fault_recovery"] == 0
    assert _sum_ok(att) == []


def test_dangling_begin_is_closed_at_window_end():
    # crash-interrupted work: a "b" whose "e" never came (the schema rejects
    # this, but the analysis layer degrades gracefully)
    tr = Tracer()
    tr.async_span("replica/0", "decode", "ok", 0.0, 5.0)
    doc = tr.to_chrome()
    pid = next(e["pid"] for e in doc["traceEvents"]
               if e["ph"] == "M" and e["name"] == "process_name"
               and e["args"]["name"] == "replica/0")
    doc["traceEvents"].append({"ph": "b", "name": "decode", "cat": "span",
                               "id": "cut", "ts": 3_000_000, "pid": pid,
                               "tid": 0})
    att = analysis.attribute(doc)
    assert att.lanes["replica/0"]["compute"] == 5_000_000
    assert _sum_ok(att) == []


def test_truncated_trace_with_orphan_ends():
    # an odd-sized ring over adjacent b/e pairs forces an "e" whose "b" was
    # evicted (an even ring keeps whole pairs)
    tr = Tracer(max_events=11)
    for k in range(20):
        tr.async_span("replica/0", "decode", f"s{k}",
                      float(k), float(k) + 0.5)
    doc = tr.to_chrome()
    assert doc["metadata"]["truncated"] is True
    schema.validate(doc)                       # lenient mode auto-applies
    with pytest.raises(schema.TraceSchemaError):
        schema.validate(doc, strict=True)
    parsed = analysis.parse_trace(doc)
    assert parsed.n_dropped_ends > 0
    att = analysis.attribute(doc)
    assert att.truncated and att.n_dropped_ends == parsed.n_dropped_ends
    assert att.window_us[0] > 0                # window starts at survivor
    assert _sum_ok(att) == []


# ---------------------------------------------------------------------------
# Attribution on recorded runs
# ---------------------------------------------------------------------------
def test_serve_attribution_sums_exactly(serve_doc):
    att = analysis.attribute(serve_doc)
    assert len(att.lanes) >= 4
    assert _sum_ok(att) == []                  # zero error, beats 1 µs bound
    assert att.totals["compute"] > 0 and att.totals["comm"] > 0
    # the 0.5-fraction crash produces downtime on the victim's lanes
    assert att.totals["fault_recovery"] > 0
    for b, v in att.totals.items():
        assert v == sum(lb[b] for lb in att.lanes.values())


def test_train_attribution_sums_exactly(train_doc):
    att = analysis.attribute(train_doc)
    task_lanes = [l for l in att.lanes if l.startswith("task/")]
    assert task_lanes
    assert _sum_ok(att) == []
    assert att.totals["compute"] > 0 and att.totals["comm"] > 0


def test_attribution_is_deterministic(serve_doc):
    doc2 = _serve_doc()
    a = json.dumps(analysis.attribute(serve_doc).to_dict(), sort_keys=True)
    b = json.dumps(analysis.attribute(doc2).to_dict(), sort_keys=True)
    assert a == b                              # byte-identical double run


def test_fast_and_reference_attribute_identically(serve_doc):
    # data-plane solver choice changes solver bookkeeping lanes, never the
    # semantic machine/replica timelines the attribution buckets
    ref = analysis.attribute(_serve_doc(data_plane="reference"))
    fast = analysis.attribute(serve_doc)
    assert fast.lanes == ref.lanes
    assert fast.totals == ref.totals


def test_explicit_window_clips(serve_doc):
    att = analysis.attribute(serve_doc)
    lo, hi = att.window_us
    mid = (lo + hi) // 2
    clipped = analysis.attribute(serve_doc, window=(lo, mid))
    assert clipped.wall_us == mid - lo
    assert _sum_ok(clipped) == []


# ---------------------------------------------------------------------------
# Critical path / waterfalls
# ---------------------------------------------------------------------------
def test_critical_path_explains_straggler_makespan(train_doc):
    cp = analysis.critical_path(train_doc)
    assert cp is not None
    assert cp.explained_fraction >= 0.95       # acceptance bound
    # segments are contiguous and in time order, ending at the makespan
    for a, b in zip(cp.segments, cp.segments[1:]):
        assert a.t1 == b.t0
    assert cp.segments[-1].t1 == cp.makespan_us
    assert sum(cp.by_kind_us.values()) == cp.explained_us
    assert cp.by_kind_us.get("compute", 0) > 0


def test_critical_path_none_for_serving_traces(serve_doc):
    assert analysis.critical_path(serve_doc) is None


def test_waterfall_phases_sum_to_latency_exactly(serve_doc):
    wf = analysis.latency_waterfall(serve_doc)
    assert wf["n_requests"] > 0
    for rid, r in wf["requests"].items():
        assert sum(r["phases_us"].values()) == r["latency_us"], rid
        assert all(v >= 0 for v in r["phases_us"].values()), rid
    for phase in analysis.WATERFALL_PHASES:
        assert phase in wf["aggregate"]


def test_waterfall_empty_for_training_traces(train_doc):
    wf = analysis.latency_waterfall(train_doc)
    assert wf["n_requests"] == 0 and wf["n_unattributed"] == 0


# ---------------------------------------------------------------------------
# Trace diff
# ---------------------------------------------------------------------------
def test_diff_of_identical_runs_is_empty(serve_doc):
    d = analysis.diff(serve_doc, serve_doc)
    assert d["wall_delta_us"] == 0
    assert all(v == 0 for v in d["totals_delta_us"].values())
    assert d["n_lane_deltas"] == 0 and d["n_span_deltas"] == 0


def test_diff_reports_top_deltas(serve_doc):
    other = _serve_doc(seed=7, traffic_seed=3)
    d = analysis.diff(serve_doc, other)
    assert d["n_span_deltas"] > 0
    deltas = [abs(r["delta_us"]) for r in d["span_deltas"]]
    assert deltas == sorted(deltas, reverse=True)
    for r in d["span_deltas"]:
        assert r["delta_us"] == r["total_us_b"] - r["total_us_a"]


# ---------------------------------------------------------------------------
# Report rendering + CLI
# ---------------------------------------------------------------------------
def test_render_trace_sections(serve_doc, train_doc):
    text = report.render_trace(serve_doc, title="serve")
    assert "trace analytics: serve" in text
    assert "latency waterfalls" in text and "critical path" not in text
    text = report.render_trace(train_doc, title="train")
    assert "critical path" in text and "latency waterfalls" not in text


def test_report_cli(tmp_path, capsys, serve_doc):
    p = tmp_path / "a.trace.json"
    p.write_text(json.dumps(serve_doc))
    assert report.main([str(p)]) == 0
    assert "trace analytics" in capsys.readouterr().out
    assert report.main([str(p), "--json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert "attribution" in out and "waterfall" in out
    assert report.main([str(p), "--diff", str(p)]) == 0
    assert "wall delta: 0.000s" in capsys.readouterr().out
