"""GPipe pipeline test — needs >1 device, so it re-executes itself in a
subprocess with XLA_FLAGS forcing 4 host CPU devices. Checks:
  * pipelined forward == serial forward
  * grads through the ppermute chain == serial grads
"""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, r"%(src)s")
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.parallel import pipeline as pp

S, M, MB, D = 4, 8, 2, 16
mesh = Mesh(np.array(jax.devices()[:S]), ("stage",))

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

key = jax.random.PRNGKey(0)
ks = jax.random.split(key, S)
per_stage = [{"w": jax.random.normal(k, (D, D)) * 0.3,
              "b": jnp.zeros((D,))} for k in ks]
stacked = pp.stack_stage_params(per_stage)
x = jax.random.normal(jax.random.PRNGKey(1), (M * MB, D))
xm = pp.microbatch(x, M)

fwd = pp.gpipe_forward(stage_fn, mesh, "stage", M)
y_pipe = fwd(stacked, xm).reshape(M * MB, D)

y_ser = x
for p in per_stage:
    y_ser = stage_fn(p, y_ser)
np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ser),
                           rtol=1e-5, atol=1e-5)

# gradient check
tgt = jax.random.normal(jax.random.PRNGKey(2), (M, MB, D))
loss_pipe = pp.gpipe_loss(stage_fn, lambda y, t: jnp.mean((y - t) ** 2),
                          mesh, "stage", M)
g_pipe = jax.grad(loss_pipe)(stacked, xm, tgt)

def loss_ser(stacked_p, x, t):
    y = x
    for s in range(S):
        p = jax.tree.map(lambda q: q[s], stacked_p)
        y = stage_fn(p, y)
    return jnp.mean((y.reshape(t.shape) - t) ** 2)

g_ser = jax.grad(loss_ser)(stacked, x, tgt)
for a, b in zip(jax.tree.leaves(g_pipe), jax.tree.leaves(g_ser)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)
print("PIPELINE_OK")
"""


def test_gpipe_subprocess():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = SCRIPT % {"src": os.path.abspath(src)}
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert "PIPELINE_OK" in res.stdout, res.stdout + res.stderr
