"""Declarative, seed-deterministic fault injection for the fleet simulator.

A ``FaultPlan`` is a tuple of typed *injectors* — each one a frozen
dataclass describing a failure mode in fleet-level terms (which region,
what fraction, how long) with every time expressed as a **fraction of the
run horizon**, the same convention the legacy ``fault_fracs`` fields used.
``compile_plan`` resolves a plan against a concrete ``ClusterGraph`` and
horizon into a flat, time-ordered list of ``FaultAction`` engine payloads;
the hosts (``sim.workload.ServeExecutor`` and
``sim.evaluate.FleetSimulation``) schedule one engine event per action
(``pin_epoch=False``, so fault events survive re-plan epoch bumps) and
dispatch on ``FaultAction.kind``:

* ``crash``      — machines die. Victims are either explicit (original
  graph ids, resolved at compile time) or drawn at *fire* time from the
  host's alive pool with ``rng((seed, 0xFA17, injector))`` — exactly the
  draw the legacy ``fault_fracs`` path used, which is what keeps the shim
  (``plan_from_fracs``) bit-identical to the old mechanism. An optional
  ``recover_after`` makes the host revive/rejoin the victims later via the
  existing tombstone/revive (serving) or ``on_join`` (training) paths.
* ``link`` / ``link_clear`` — a named bandwidth/latency overlay on a set of
  machine pairs (``NetworkModel.apply_link_fault``); ``cut=True`` severs
  the pairs entirely (region partition). Overlays compose multiplicatively
  and heal when cleared.
* ``gray`` / ``gray_clear``  — a silent slowdown multiplier on a machine
  (``ComputeModel.set_gray``); ramps compile to a staircase of ``gray``
  actions so a gray failure can creep in instead of arriving step-shaped.

Every random choice is keyed on ``(seed, stream, injector_index)`` —
counter-based, never order-dependent — so a plan replays bit-identically
and two hosts given the same plan + seed inject the same faults.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.graph import ClusterGraph

# RNG stream constants (crash fire-time draws reuse the legacy 0xFA17 key)
CRASH_STREAM = 0xFA17
_PREEMPT_STREAM = 0x9E61
_GRAY_STREAM = 0x6EA1
_FLAP_STREAM = 0xF1A9


# ---------------------------------------------------------------------------
# Injectors
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MachineCrash:
    """Kill machines at ``at`` (fraction of the horizon).

    With explicit ``machines`` (original graph ids) the crash is
    *machine-level*: the nodes tombstone out of the network/compute models
    and stop relaying traffic. With ``machines=()`` the host draws
    ``kills`` victims from its alive pool at fire time — the legacy
    ``fault_fracs`` semantics (serving: replica processes die, their
    machines keep routing). ``recover_after`` (fraction of the horizon,
    measured from the crash) revives the victims and rejoins them.
    """
    at: float
    kills: int = 1
    machines: tuple[int, ...] = ()
    recover_after: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class RegionPreemption:
    """Correlated preemption wave: a ``frac`` of ``region``'s machines
    (chosen with ``rng((seed, 0x9E61, injector))`` at compile time) die
    together — the spot-market event that kills a whole zone at once."""
    at: float
    region: str
    frac: float = 1.0
    recover_after: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class LinkDegradation:
    """Between ``at`` and ``at + duration``: the links between the two
    ``regions`` (or the explicit machine-id ``pairs``) run at
    ``bw_factor`` x bandwidth and ``lat_factor`` x latency."""
    at: float
    duration: float
    regions: Optional[tuple[str, str]] = None
    pairs: tuple[tuple[int, int], ...] = ()
    bw_factor: float = 1.0
    lat_factor: float = 1.0


@dataclasses.dataclass(frozen=True)
class RegionPartition:
    """Between ``at`` and ``at + duration``: every link between
    ``regions`` and the rest of the fleet is severed, then heals."""
    at: float
    duration: float
    regions: tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class GrayFailure:
    """Machines silently slow down by ``slowdown``x — alive, routable, just
    degraded (the failure mode health checks miss). ``ramp`` spreads the
    onset over that fraction of the horizon in ``ramp_steps`` increments;
    ``duration=None`` means the machine never recovers within the run."""
    at: float
    machines: tuple[int, ...] = ()
    picks: int = 1
    slowdown: float = 3.0
    ramp: float = 0.0
    ramp_steps: int = 4
    duration: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class MachineFlap:
    """A machine repeatedly crashes and recovers: ``cycles`` x
    (``down`` fraction dead, ``up`` fraction alive). ``machine=None``
    draws one with ``rng((seed, 0xF1A9, injector))`` at compile time."""
    at: float
    machine: Optional[int] = None
    down: float = 0.02
    up: float = 0.05
    cycles: int = 2


Injector = Union[MachineCrash, RegionPreemption, LinkDegradation,
                 RegionPartition, GrayFailure, MachineFlap]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    injectors: tuple = ()

    def __bool__(self) -> bool:
        return bool(self.injectors)


@dataclasses.dataclass(frozen=True)
class FaultAction:
    """One resolved engine event: ``t`` is absolute sim seconds."""
    t: float
    kind: str            # crash | link | link_clear | gray | gray_clear
    payload: dict
    injector: int        # index into plan.injectors (rng key + trace label)


def plan_from_fracs(fault_fracs: Sequence[float],
                    kills_per_fault: int = 1) -> FaultPlan:
    """The legacy ``fault_fracs``/``kills_per_fault`` fields as a plan:
    one drawn-at-fire-time crash per fraction, no recovery — compiles to
    the exact event schedule (and rng keys) the old mechanism produced."""
    return FaultPlan(tuple(MachineCrash(at=float(f), kills=kills_per_fault)
                           for f in fault_fracs))


def has_link_faults(plan: Optional[FaultPlan]) -> bool:
    return plan is not None and any(
        isinstance(inj, (LinkDegradation, RegionPartition))
        for inj in plan.injectors)


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------
def _region_ids(graph: ClusterGraph, region: str) -> list[int]:
    return [i for i, m in enumerate(graph.machines) if m.region == region]


def _cross_pairs(graph: ClusterGraph, a: str, b: str) -> list[tuple[int, int]]:
    ia, ib = _region_ids(graph, a), _region_ids(graph, b)
    return [(i, j) for i in ia for j in ib]


def _partition_pairs(graph: ClusterGraph,
                     regions: Sequence[str]) -> list[tuple[int, int]]:
    group = {i for r in regions for i in _region_ids(graph, r)}
    rest = [i for i in range(graph.n) if i not in group]
    return [(i, j) for i in sorted(group) for j in rest]


def compile_plan(plan: FaultPlan, graph: ClusterGraph, horizon: float,
                 seed: int = 0) -> list[FaultAction]:
    """Resolve a plan against a concrete fleet + horizon. Actions come out
    in injector order (ties in time resolve by emission order, matching how
    the legacy loop scheduled its events); all machine ids in payloads are
    *original* ids of ``graph`` — hosts whose ids drift (compaction after a
    failure) translate at apply time."""
    actions: list[FaultAction] = []
    for idx, inj in enumerate(plan.injectors):
        t0 = float(inj.at) * horizon
        if isinstance(inj, MachineCrash):
            rec = (None if inj.recover_after is None
                   else float(inj.recover_after) * horizon)
            actions.append(FaultAction(t0, "crash", {
                "kills": int(inj.kills),
                "machines": tuple(int(m) for m in inj.machines),
                "recover_after_s": rec}, idx))
        elif isinstance(inj, RegionPreemption):
            ids = _region_ids(graph, inj.region)
            if not ids:
                continue
            k = max(1, int(round(inj.frac * len(ids))))
            if k < len(ids):
                rng = np.random.default_rng((seed, _PREEMPT_STREAM, idx))
                ids = sorted(int(i) for i in
                             rng.choice(ids, size=k, replace=False))
            rec = (None if inj.recover_after is None
                   else float(inj.recover_after) * horizon)
            actions.append(FaultAction(t0, "crash", {
                "kills": len(ids), "machines": tuple(ids),
                "recover_after_s": rec}, idx))
        elif isinstance(inj, LinkDegradation):
            pairs = (tuple(_cross_pairs(graph, *inj.regions))
                     if inj.regions is not None
                     else tuple((int(a), int(b)) for a, b in inj.pairs))
            if not pairs:
                continue
            actions.append(FaultAction(t0, "link", {
                "pairs": pairs, "bw_factor": float(inj.bw_factor),
                "lat_factor": float(inj.lat_factor), "cut": False}, idx))
            actions.append(FaultAction(t0 + float(inj.duration) * horizon,
                                       "link_clear", {"fault_id": idx}, idx))
        elif isinstance(inj, RegionPartition):
            pairs = tuple(_partition_pairs(graph, inj.regions))
            if not pairs:
                continue
            actions.append(FaultAction(t0, "link", {
                "pairs": pairs, "bw_factor": 1.0, "lat_factor": 1.0,
                "cut": True}, idx))
            actions.append(FaultAction(t0 + float(inj.duration) * horizon,
                                       "link_clear", {"fault_id": idx}, idx))
        elif isinstance(inj, GrayFailure):
            machines = [int(m) for m in inj.machines if m < graph.n]
            if not machines and graph.n > 0:
                rng = np.random.default_rng((seed, _GRAY_STREAM, idx))
                k = min(max(1, int(inj.picks)), graph.n)
                machines = sorted(int(i) for i in
                                  rng.choice(graph.n, size=k, replace=False))
            steps = max(1, int(inj.ramp_steps)) if inj.ramp > 0 else 1
            for s in range(1, steps + 1):
                t = t0 + float(inj.ramp) * horizon * s / steps
                # linear creep from 1 -> slowdown across the ramp
                f = 1.0 + (float(inj.slowdown) - 1.0) * s / steps
                for m in machines:
                    actions.append(FaultAction(t, "gray",
                                               {"machine": m, "factor": f},
                                               idx))
            if inj.duration is not None:
                t_end = t0 + float(inj.duration) * horizon
                for m in machines:
                    actions.append(FaultAction(t_end, "gray_clear",
                                               {"machine": m}, idx))
        elif isinstance(inj, MachineFlap):
            if inj.machine is None:
                if graph.n == 0:
                    continue
                rng = np.random.default_rng((seed, _FLAP_STREAM, idx))
                m = int(rng.integers(0, graph.n))
            else:
                m = int(inj.machine)
            t = t0
            for _ in range(max(1, int(inj.cycles))):
                actions.append(FaultAction(t, "crash", {
                    "kills": 1, "machines": (m,),
                    "recover_after_s": float(inj.down) * horizon}, idx))
                t += (float(inj.down) + float(inj.up)) * horizon
        else:
            raise TypeError(f"unknown fault injector {type(inj).__name__}")
    return actions
