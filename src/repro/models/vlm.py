"""VLM backbone (InternVL2-style): ViT frontend STUB + MLP projector + LM.

``input_specs`` provides precomputed InternViT patch embeddings
(B, n_patches, vit_dim); the projector maps them into the LM embedding space
and they are prepended to the text tokens. Loss is computed on text positions
only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decoder_lm as dlm
from repro.models.common import cross_entropy, dense_init


def init_params(cfg: ModelConfig, key) -> dict:
    k_lm, k1, k2 = jax.random.split(key, 3)
    params = dlm.init_params(cfg, k_lm)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    params["projector"] = {
        "w1": dense_init(k1, cfg.vit_dim, cfg.d_model, dt),
        "w2": dense_init(k2, cfg.d_model, cfg.d_model, dt),
    }
    return params


def _embed_multimodal(params, cfg: ModelConfig, patches, tokens):
    proj = jax.nn.gelu(patches @ params["projector"]["w1"]) \
        @ params["projector"]["w2"]
    text = params["embed"][tokens]
    return jnp.concatenate([proj.astype(text.dtype), text], axis=1)


def loss_and_metrics(params, cfg: ModelConfig, batch: dict):
    """batch: patches (B,P,vit_dim), tokens (B,S), labels (B,S)."""
    embeds = _embed_multimodal(params, cfg, batch["patches"], batch["tokens"])
    logits, aux, _ = dlm.forward(params, cfg, embeds=embeds)
    p = batch["patches"].shape[1]
    text_logits = logits[:, p:]
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    ce = cross_entropy(text_logits, jnp.maximum(labels, 0), mask)
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


def prefill(params, cfg: ModelConfig, patches, tokens, max_len: int = 0):
    embeds = _embed_multimodal(params, cfg, patches, tokens)
    return dlm.prefill(params, cfg, embeds=embeds, max_len=max_len)


decode_step = dlm.decode_step
