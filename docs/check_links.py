"""Dependency-free link checker for the docs tree (CI `docs` job).

Scans every Markdown file in docs/ plus the top-level README/ROADMAP for
inline links and validates the relative ones: the target file (anchor
stripped) must exist relative to the linking file. External (http/https/
mailto) links are not fetched — CI must stay hermetic.

    python docs/check_links.py
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = [os.path.join(REPO, "README.md"), os.path.join(REPO, "ROADMAP.md")]
for root, _, files in os.walk(os.path.join(REPO, "docs")):
    DOC_FILES += [os.path.join(root, f) for f in files if f.endswith(".md")]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def main() -> int:
    bad: list[str] = []
    n_links = 0
    for path in sorted(DOC_FILES):
        if not os.path.exists(path):
            bad.append(f"{path}: file listed for checking does not exist")
            continue
        with open(path) as f:
            text = f.read()
        base = os.path.dirname(path)
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            n_links += 1
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not os.path.exists(os.path.normpath(os.path.join(base, rel))):
                bad.append(f"{os.path.relpath(path, REPO)}: broken link "
                           f"-> {target}")
    if bad:
        print("\n".join(bad))
        print(f"FAIL: {len(bad)} broken link(s)")
        return 1
    print(f"OK: {n_links} relative links across {len(DOC_FILES)} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
