"""Batched serving example: prefill a batch of prompts through gemma3-1b
(CPU-reduced) and greedy-decode continuations — the serve_step that the
decode_* dry-run shapes lower at production scale.

    PYTHONPATH=src python examples/serve_batch.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.data.synthetic import SyntheticConfig, make_batch
from repro.launch.serve import serve_batch
from repro.models.registry import get_api


def main():
    cfg = dataclasses.replace(reduce_for_smoke(get_config("gemma3-1b")),
                              remat=False)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    batch = {k: jnp.asarray(v) for k, v in make_batch(
        cfg, SyntheticConfig(global_batch=4, seq_len=32, seed=0), 0).items()}
    gen, stats = serve_batch(cfg, params, batch, gen_tokens=16)
    print(f"batch of 4 requests -> 16 tokens each "
          f"({stats['tokens_per_s']:.1f} decode tok/s)")
    for i, row in enumerate(gen):
        print(f"  request {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
