"""Pallas TPU flash-decode: one query token vs a blocked KV cache.

The decode_32k / long_500k hot spot is memory-bound (the whole KV cache
streams HBM->VMEM once per token). The kernel tiles the cache T dim; the
running (m, l, acc) online-softmax state lives in VMEM scratch across the kv
grid dim. Validity is a per-slot int32 mask (ring caches mark stale slots),
so the same kernel serves full and sliding-window caches.

Layout: q (B, H, D); k/v (B, KV, T, D); valid (T,) int32 -> o (B, H, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_KV = 512
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D) — group heads
    k = k_ref[0, 0].astype(jnp.float32)            # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    valid = valid_ref[...] > 0                     # (1, BK)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid, s, NEG_INF)               # (H, BK)

    m_prev = m_scr[...][:, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_scr[...] = (l_scr[...][:, 0] * alpha + jnp.sum(p, axis=1))[:, None]
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur[:, None]

    @pl.when(ki == nk - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_grouped(q, k, v, valid, *,
                             block_kv: int = DEFAULT_BLOCK_KV,
                             interpret: bool = True):
    """q (B, KV, G, D) — queries grouped by kv head; k/v (B, KV, T, D);
    valid (1, T) int32. Returns (B, KV, G, D)."""
    b, kvh, g, d = q.shape
    t = k.shape[2]
    nk = t // block_kv
    grid = (b, kvh, nk)
    kernel = functools.partial(_decode_kernel, scale=d ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, hh, ki: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, hh, ki: (bb, hh, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, hh, ki: (bb, hh, ki, 0)),
            pl.BlockSpec((1, block_kv), lambda bb, hh, ki: (0, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bb, hh, ki: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)
