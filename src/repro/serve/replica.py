"""Continuous-batching replica model (vLLM/aphrodite-style mechanics).

A ``Replica`` owns one machine of the ``ClusterGraph`` and runs an iteration
loop over the discrete-event engine:

* an **admission queue** holds routed requests until there is both a batch
  slot and KV room; KV is reserved for the whole sequence (prompt + max
  generation) at admission, so a sequence admitted once can never be
  preempted by memory pressure — the conservative reservation real engines
  use when they disable swapping;
* each **iteration** interleaves prefill and decode: sequences still
  prefilling contribute a chunk of prompt tokens (chunked prefill), every
  decoded sequence contributes exactly one token. The iteration's duration
  is the efficiency-adjusted FLOPs priced by ``serve.costs`` divided through
  the machine's FLOP/s — i.e. ``sim.compute.ComputeModel.duration``, so
  straggler/jitter modeling applies to serving for free;
* completions free their KV reservation and fire the router's callback
  (which moves the response back over the network).

Fleet-scale fast path (PR 4): iteration starts are deferred by one
zero-delay event so every request routed at the same timestamp is admitted
into the SAME first batch (an idle replica no longer launches a batch-of-one
for the first arrival of a burst), and the router's load signal
(``backlog_work``) is maintained as two integer token counters instead of a
per-query sweep over the queue — ``pick`` cost no longer scales with queue
depth. ``backlog_work_reference`` keeps the original sweep for equivalence
tests.

Calibration contract (asserted in tests/test_serve.py): with zero jitter and
an idle network, a request's time inside the replica is exactly
``ServeModel.service_s(prompt, gen, tflops)`` — chunking only splits the
work across iterations, it never adds any.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Optional

from repro import obs as obs_mod
from repro.serve.costs import ServeModel
from repro.serve.traffic import Request
from repro.sim.compute import ComputeModel
from repro.sim.engine import Event, Simulator

# keeps replica-iteration jitter streams disjoint from the training tags
_TAG_SERVE = 4


@dataclasses.dataclass
class Seq:
    """One admitted request's in-flight decoding state."""
    req: Request
    done_cb: Callable[["Seq"], None]
    t_enqueue: float
    prefill_remaining: int = 0
    decode_remaining: int = 0
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def kv_tokens(self) -> int:
        return self.req.total_tokens


class Replica:
    def __init__(self, sim: Simulator, compute: ComputeModel, machine_id: int,
                 model: ServeModel, memory_gb: float, *, max_batch: int = 8,
                 prefill_chunk: int = 256, name: str | None = None,
                 reference_backlog: bool = False, obs=None):
        self._obs = obs if obs is not None else obs_mod.NULL
        self.sim = sim
        self.compute = compute
        self.machine = int(machine_id)
        self.model = model
        self.name = name or f"replica@{machine_id}"
        self.max_batch = max_batch
        self.prefill_chunk = prefill_chunk
        self.reference_backlog = reference_backlog
        self.kv_capacity = model.kv_capacity_tokens(memory_gb)
        self.kv_used = 0
        self.queue: collections.deque[Seq] = collections.deque()
        self.running: list[Seq] = []
        self.alive = True
        self.accepting = True           # False while draining
        self.it = 0                     # iteration counter (jitter key)
        self.busy_s = 0.0
        self.tokens_decoded = 0
        self.tokens_prefilled = 0
        self.batch_occupancy: float = 0.0   # time-integral of batch size
        self._iter_ev: Optional[Event] = None
        self._kick_ev: Optional[Event] = None   # deferred iteration start
        self._idle_cb: Optional[Callable[[], None]] = None
        # pending-token counters (queued + in flight); integers, so the
        # incremental backlog is exact, not a float accumulation
        self._pending_prefill = 0
        self._pending_decode = 0

    # -- queries -------------------------------------------------------------
    def fits(self, req: Request) -> bool:
        """Can this replica EVER hold the request? (KV reservation bound)"""
        return req.total_tokens <= self.kv_capacity

    def n_pending(self) -> int:
        return len(self.queue) + len(self.running)

    def backlog_work(self) -> float:
        """Effective FLOPs of everything queued or in flight — the router's
        load signal. O(1): ``service_work`` is linear in tokens, so the sum
        over sequences equals the work of the summed token counts."""
        return self.model.prefill_work(self._pending_prefill) \
            + self.model.decode_work(self._pending_decode)

    def backlog_work_reference(self) -> float:
        """The original O(queue + batch) backlog sweep, kept as the
        equivalence oracle for the counter-based ``backlog_work``."""
        w = 0.0
        for s in self.queue:
            w += self.model.service_work(s.req.prompt_tokens,
                                         s.req.gen_tokens)
        for s in self.running:
            w += self.model.prefill_work(s.prefill_remaining) \
                + self.model.decode_work(s.decode_remaining)
        return w

    def est_wait_s(self) -> float:
        tf = float(self.compute.tflops[self.machine]) * 1e12
        work = self.backlog_work_reference() if self.reference_backlog \
            else self.backlog_work()
        return work / tf

    # -- request flow --------------------------------------------------------
    def submit(self, req: Request, done_cb: Callable[[Seq], None]) -> Seq:
        assert self.alive and self.accepting
        seq = Seq(req=req, done_cb=done_cb, t_enqueue=self.sim.now,
                  prefill_remaining=req.prompt_tokens,
                  decode_remaining=req.gen_tokens)
        self.queue.append(seq)
        self._pending_prefill += req.prompt_tokens
        self._pending_decode += req.gen_tokens
        self._maybe_iterate()
        return seq

    def _admit(self) -> None:
        while (self.queue and len(self.running) < self.max_batch
               and self.kv_used + self.queue[0].kv_tokens
               <= self.kv_capacity):
            seq = self.queue.popleft()
            seq.t_admit = self.sim.now
            self.kv_used += seq.kv_tokens
            self.running.append(seq)

    def _maybe_iterate(self) -> None:
        """Arm the next iteration. The start is deferred by one zero-delay
        event so every submit at the current timestamp joins the batch —
        without it, the first request of a same-tick burst would launch a
        batch of one and the rest would wait a full iteration."""
        if not self.alive or self._iter_ev is not None \
                or self._kick_ev is not None:
            return
        if not (self.queue or self.running):
            return
        self._kick_ev = self.sim.schedule(0.0, self._start_iteration)

    def _start_iteration(self) -> None:
        self._kick_ev = None
        if not self.alive or self._iter_ev is not None:
            return
        self._admit()
        if not self.running:
            return
        # one cost-card call per phase, not per sequence: decode tokens are
        # identical (1 each), so the batch prices as decode_work(n_decoding)
        chunk = self.prefill_chunk
        prefill_tokens = 0
        n_decoding = 0
        for s in self.running:
            if s.prefill_remaining > 0:
                prefill_tokens += chunk if s.prefill_remaining > chunk \
                    else s.prefill_remaining
            else:
                n_decoding += 1
        work = self.model.prefill_work(prefill_tokens) \
            + self.model.decode_work(n_decoding)
        dur = self.compute.duration(self.machine, work, step=self.it,
                                    microbatch=0, tag=_TAG_SERVE)
        self.busy_s += dur
        self.batch_occupancy += dur * len(self.running)
        if self._obs.enabled and work > 0:
            # actual / zero-jitter duration: >1 under straggle, gray failure
            # or jitter — the per-machine drift signal obs.monitors EWMAs
            base = work / (float(self.compute.tflops[self.machine]) * 1e12)
            if base > 0:
                self._obs.metrics.observe(
                    f"replica.slowdown.m{self.machine}", dur / base)
        self._iter_ev = self.sim.schedule(dur, self._finish_iteration)

    def _finish_iteration(self) -> None:
        self._iter_ev = None
        if not self.alive:
            return
        self.it += 1
        done: list[Seq] = []
        for s in self.running:
            if s.prefill_remaining > 0:
                chunk = min(self.prefill_chunk, s.prefill_remaining)
                s.prefill_remaining -= chunk
                self.tokens_prefilled += chunk
                self._pending_prefill -= chunk
            else:
                s.decode_remaining -= 1
                self.tokens_decoded += 1
                self._pending_decode -= 1
                if s.t_first_token is None:
                    s.t_first_token = self.sim.now
                if s.decode_remaining == 0:
                    done.append(s)
        for s in done:
            self.running.remove(s)
            self.kv_used -= s.kv_tokens
            s.t_done = self.sim.now
        if self._obs.enabled:
            self._obs.metrics.inc("replica.iterations")
            if done:
                self._record_done(done)
        # continue the batch inline — the deferred (zero-delay-event) start
        # is only needed on the idle->busy edge, where it lets a same-tick
        # burst of submits share the first batch; a replica mid-stream
        # admits at its own iteration boundary, like a real engine
        self._start_iteration()
        # callbacks last: they may route new work back into this replica
        for s in done:
            s.done_cb(s)
        if self._idle_cb is not None and not self.running and not self.queue:
            cb, self._idle_cb = self._idle_cb, None
            cb()

    def _record_done(self, done: list[Seq]) -> None:
        """Emit the request lifecycle spans (queued -> prefill -> decode ->
        done) for each completed sequence on this replica's lane. All four
        timestamps were recorded on the ``Seq`` as the engine fired them, so
        emitting retroactively at completion keeps the hot iteration loop
        free of tracing branches; async spans, because a batch completes many
        overlapping sequences on one lane."""
        trace = self._obs.trace
        metrics = self._obs.metrics
        track = f"replica/{self.machine}"
        for s in done:
            sid = f"r{s.req.rid}"
            first = s.t_first_token if s.t_first_token is not None else s.t_done
            trace.async_span(track, "queued", sid, s.t_enqueue, s.t_admit,
                             cat="request", args={"rid": s.req.rid})
            trace.async_span(track, "prefill", sid, s.t_admit, first,
                             cat="request",
                             args={"tokens": s.req.prompt_tokens})
            trace.async_span(track, "decode", sid, first, s.t_done,
                             cat="request",
                             args={"tokens": s.req.gen_tokens})
            metrics.inc("replica.seqs_completed")
            metrics.observe("serve.queue_wait_s", s.t_admit - s.t_enqueue)
            metrics.observe("serve.service_s", s.t_done - s.t_admit)

    # -- lifecycle -----------------------------------------------------------
    def drain(self) -> list[Request]:
        """Stop admitting; return the not-yet-admitted requests so the
        router can place them elsewhere. In-flight sequences finish."""
        self.accepting = False
        dropped = [s.req for s in self.queue]
        for s in self.queue:
            self._pending_prefill -= s.req.prompt_tokens
            self._pending_decode -= s.req.gen_tokens
        self.queue.clear()
        return dropped

    def when_idle(self, cb: Callable[[], None]) -> None:
        """Run ``cb`` once nothing is queued or in flight (fires immediately
        if already idle). Used by the executor to deprovision a drained
        replica's machine only after its last response has left."""
        if not self.running and not self.queue:
            cb()
        else:
            self._idle_cb = cb

    def abort(self, seq: Seq) -> bool:
        """Cancel one sequence (attempt timed out / lost a hedge race).
        Returns False if the sequence already completed or left this replica.
        A running sequence frees its KV reservation immediately; the current
        iteration still runs to completion (the abort takes effect at the
        next batch boundary, like a real engine's cancellation)."""
        if seq in self.queue:
            self.queue.remove(seq)
            self._pending_prefill -= seq.prefill_remaining
            self._pending_decode -= seq.decode_remaining
            if self._obs.enabled:
                self._obs.metrics.inc("replica.seqs_aborted")
            return True
        if seq in self.running:
            self.running.remove(seq)
            self.kv_used -= seq.kv_tokens
            self._pending_prefill -= seq.prefill_remaining
            self._pending_decode -= seq.decode_remaining
            if self._obs.enabled:
                self._obs.metrics.inc("replica.seqs_aborted")
            return True
        return False

    def fail(self) -> list[Request]:
        """Machine died: every queued AND in-flight request is interrupted
        and handed back for re-routing (generation restarts from scratch —
        no cross-replica KV migration yet)."""
        self.alive = False
        self.accepting = False
        self._idle_cb = None
        if self._iter_ev is not None:
            self._iter_ev.cancel()
            self._iter_ev = None
        if self._kick_ev is not None:
            self._kick_ev.cancel()
            self._kick_ev = None
        interrupted = [s.req for s in self.queue] \
            + [s.req for s in self.running]
        self.queue.clear()
        self.running.clear()
        self.kv_used = 0
        self._pending_prefill = 0
        self._pending_decode = 0
        return interrupted

    def stats(self) -> dict:
        return {
            "machine": self.machine,
            "busy_s": self.busy_s,
            "iterations": self.it,
            "tokens_decoded": self.tokens_decoded,
            "tokens_prefilled": self.tokens_prefilled,
            "mean_batch": (self.batch_occupancy / self.busy_s
                           if self.busy_s > 0 else 0.0),
            "kv_capacity_tokens": self.kv_capacity,
            "alive": self.alive,
        }
