"""jit wrapper: layout conversion, padding to block multiples, backend
selection (Pallas on TPU / interpret elsewhere / jnp reference fallback)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention import ref as _ref


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "force_ref"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = _k.DEFAULT_BLOCK_Q,
                    block_kv: int = _k.DEFAULT_BLOCK_KV,
                    force_ref: bool = False):
    """Public API — model layout: q (B, S, H, D); k/v (B, T, KV, D)."""
    if force_ref:
        return _ref.attention_ref(q, k, v, causal=causal, window=window)
    b, s, h, d = q.shape
    t = k.shape[1]
    bq = min(block_q, max(8, 1 << (s - 1).bit_length()))
    bk = min(block_kv, max(8, 1 << (t - 1).bit_length()))
    qt = _pad_to(q.transpose(0, 2, 1, 3), 2, bq)       # (B, H, S', D)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, bk)       # (B, KV, T', D)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, bk)
    interpret = jax.default_backend() != "tpu"
    o = _k.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                block_q=bq, block_kv=bk, seq_kv=t,
                                interpret=interpret)
    return o[:, :, :s].transpose(0, 2, 1, 3)
