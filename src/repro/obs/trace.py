"""Structured span/event recorder with Chrome-trace (Perfetto) JSON export.

A ``Tracer`` records a deterministic stream of trace events against named
*tracks* ("machine/3", "replica/7", "engine/dispatch", ...). Tracks map onto
the Chrome Trace Event Format's (pid, tid) plane: every track becomes its own
process lane (with a ``process_name`` metadata event), so a fleet run opened
in Perfetto (https://ui.perfetto.dev) renders as one lane per machine, link,
replica and subsystem.

Clocks are *simulation time*: the engine binds ``tracer.now`` to its own
``sim.now`` (see ``Recorder.bind_clock``), timestamps are emitted as integer
microseconds, and events are appended in execution order — which the engine
already makes deterministic via its ``(time, seq)`` heap ordering. No wall
clock ever enters an event, so two same-seed runs serialize to byte-identical
files (asserted in tests/test_obs.py and the CI trace-smoke job).

Event kinds (Chrome ``ph`` codes):

* ``span_at``    — a complete slice (``"X"``) for strictly sequential work on
  a track (engine dispatch, cold starts);
* ``async_span`` — a nestable async begin/end pair (``"b"``/``"e"``) for
  work that overlaps on one track (concurrent flows on a machine, batched
  request phases on a replica);
* ``instant``    — a point event (``"i"``): failovers, drops, re-plans;
* ``counter``    — a counter sample (``"C"``) Perfetto plots as a graph.

Bounded mode: ``max_events`` turns the event store into a ring buffer (a
``collections.deque(maxlen=...)``), so always-on tracing of a long run keeps
the most recent window at O(max_events) memory. Eviction is deterministic
(FIFO over a deterministic stream), so bounded traces stay byte-identical
across same-seed runs too.
"""
from __future__ import annotations

import collections
import json
from typing import Callable, Optional

SCHEMA_VERSION = "repro.obs/1"


def _us(t: float) -> int:
    """Seconds -> integer microseconds (ints serialize byte-stably)."""
    return int(round(t * 1e6))


class Span:
    """Handle returned by ``Tracer.begin``; ``end()`` emits the slice."""

    __slots__ = ("_tracer", "_track", "_name", "_cat", "_t0")

    def __init__(self, tracer: "Tracer", track: str, name: str, cat: str,
                 t0: float):
        self._tracer = tracer
        self._track = track
        self._name = name
        self._cat = cat
        self._t0 = t0

    def end(self, args: Optional[dict] = None) -> None:
        self._tracer.span_at(self._track, self._name, self._t0,
                             self._tracer.now(), cat=self._cat, args=args)


class Tracer:
    def __init__(self, max_events: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.max_events = max_events
        self._events: collections.deque = collections.deque(maxlen=max_events)
        self.now: Callable[[], float] = clock or (lambda: 0.0)
        self._pids: dict[str, int] = {}      # track -> pid (one lane each)
        self.n_emitted = 0                   # includes ring-evicted events

    # -- track registry ------------------------------------------------------
    def _pid(self, track: str) -> int:
        pid = self._pids.get(track)
        if pid is None:
            pid = len(self._pids) + 1        # first-use order: deterministic
            self._pids[track] = pid
        return pid

    def _emit(self, ev: dict) -> None:
        self.n_emitted += 1
        self._events.append(ev)

    # -- recording API -------------------------------------------------------
    def begin(self, track: str, name: str, cat: str = "span") -> Span:
        """Open a slice at the current sim time; ``Span.end()`` closes it."""
        return Span(self, track, name, cat, self.now())

    def span_at(self, track: str, name: str, t0: float,
                t1: Optional[float] = None, cat: str = "span",
                args: Optional[dict] = None) -> None:
        """A complete slice [t0, t1] (t1 defaults to now). Use only for work
        that never overlaps itself on the track; overlapping work must use
        ``async_span`` so Perfetto can stack it."""
        t1 = self.now() if t1 is None else t1
        ev = {"ph": "X", "name": name, "cat": cat, "ts": _us(t0),
              "dur": max(0, _us(t1) - _us(t0)), "pid": self._pid(track),
              "tid": 0}
        if args:
            ev["args"] = args
        self._emit(ev)

    def async_span(self, track: str, name: str, span_id: str, t0: float,
                   t1: Optional[float] = None, cat: str = "span",
                   args: Optional[dict] = None) -> None:
        """A nestable async slice [t0, t1]: overlap-safe (concurrent flows,
        batched request phases). ``span_id`` groups nested phases."""
        t1 = self.now() if t1 is None else t1
        pid = self._pid(track)
        b = {"ph": "b", "name": name, "cat": cat, "id": span_id,
             "ts": _us(t0), "pid": pid, "tid": 0}
        if args:
            b["args"] = args
        self._emit(b)
        self._emit({"ph": "e", "name": name, "cat": cat, "id": span_id,
                    "ts": _us(t1), "pid": pid, "tid": 0})

    def instant(self, track: str, name: str, cat: str = "event",
                args: Optional[dict] = None) -> None:
        ev = {"ph": "i", "name": name, "cat": cat, "ts": _us(self.now()),
              "pid": self._pid(track), "tid": 0, "s": "p"}
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, track: str, name: str, value: float,
                cat: str = "counter") -> None:
        self._emit({"ph": "C", "name": name, "cat": cat,
                    "ts": _us(self.now()), "pid": self._pid(track), "tid": 0,
                    "args": {name: value}})

    # -- export --------------------------------------------------------------
    def to_chrome(self, metadata: Optional[dict] = None) -> dict:
        """The Chrome Trace Event Format document. ``metadata`` is embedded
        verbatim — callers must keep wall-clock values out of it when they
        rely on byte-identical traces."""
        meta_events = []
        for track, pid in sorted(self._pids.items(), key=lambda kv: kv[1]):
            meta_events.append({"ph": "M", "name": "process_name", "pid": pid,
                                "tid": 0, "args": {"name": track}})
            meta_events.append({"ph": "M", "name": "process_sort_index",
                                "pid": pid, "tid": 0,
                                "args": {"sort_index": pid}})
        doc = {
            "displayTimeUnit": "ms",
            "metadata": dict(metadata or {}, schema=SCHEMA_VERSION,
                             clock="sim_time_us",
                             n_emitted=self.n_emitted,
                             truncated=(self.max_events is not None
                                        and self.n_emitted > self.max_events)),
            "traceEvents": meta_events + list(self._events),
        }
        return doc

    def json_bytes(self, metadata: Optional[dict] = None) -> bytes:
        """Canonical serialization: sorted keys, compact separators — the
        byte-identity contract is over this exact encoding."""
        return json.dumps(self.to_chrome(metadata), sort_keys=True,
                          separators=(",", ":")).encode("utf-8")

    def write(self, path: str, metadata: Optional[dict] = None) -> None:
        with open(path, "wb") as f:
            f.write(self.json_bytes(metadata))


class NullSpan:
    __slots__ = ("_tracer",)

    def __init__(self, tracer: "NullTracer"):
        self._tracer = tracer

    def end(self, args: Optional[dict] = None) -> None:
        self._tracer.calls += 1


class NullTracer:
    """Disabled tracer: every method is a counted no-op. The call counter is
    how tests/test_obs.py proves the hot paths make ZERO recorder calls (and
    hence zero recording allocations) when observability is off."""

    def __init__(self) -> None:
        self.calls = 0
        self._span = NullSpan(self)

    def begin(self, track: str, name: str, cat: str = "span") -> NullSpan:
        self.calls += 1
        return self._span

    def span_at(self, *a, **kw) -> None:
        self.calls += 1

    def async_span(self, *a, **kw) -> None:
        self.calls += 1

    def instant(self, *a, **kw) -> None:
        self.calls += 1

    def counter(self, *a, **kw) -> None:
        self.calls += 1
