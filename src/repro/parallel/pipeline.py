"""GPipe pipeline parallelism with shard_map + collective_permute.

The jax-native mapping of the paper's GPipe substrate (SS2.1): stages live on
a mesh axis; microbatches march through the stage chain with
``jax.lax.ppermute`` handing activations to the next stage each tick
(fwd: perm i->i+1). ``jax.grad`` differentiates straight through — the
transpose of ppermute is ppermute with the inverse permutation, which IS the
backward activation-gradient hop, so one definition serves fwd+bwd.

Schedule (classic GPipe): T = n_micro + n_stages - 1 ticks; stage s works on
microbatch t - s at tick t (bubble fraction (S-1)/(M+S-1)).

Used by the Hulk placement layer when the cost model picks pipeline for the
slow axis (placement.RuntimePlacement.pod_axis_strategy == "pipeline").
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.4.35 re-exports shard_map at top level
    from jax import shard_map
except ImportError:  # older jax: experimental home
    from jax.experimental.shard_map import shard_map

PyTree = Any


def gpipe_forward(stage_fn: Callable, mesh: Mesh, axis: str,
                  n_microbatches: int):
    """Build fn(stacked_params, x_microbatched) -> y_microbatched.

    * ``stage_fn(params_s, x)`` — one stage's computation (same signature on
      every stage; heterogeneous pipelines stack per-stage params).
    * stacked_params: every leaf (n_stages, ...) — sharded dim0 over `axis`.
    * x: (n_microbatches, mb_size, ...) — replicated over `axis`; stage 0
      consumes it, the last stage's outputs are collected and returned.
    """
    n_stages = mesh.shape[axis]

    def per_stage(params, x):
        # params: (1, ...) local slice -> squeeze; x: full (M, mb, ...)
        params = jax.tree.map(lambda p: p[0], params)
        stage_id = jax.lax.axis_index(axis)
        total = n_microbatches + n_stages - 1
        mb_shape = x.shape[1:]

        perm_fwd = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            state = carry            # (mb, ...) activation entering this stage
            # stage 0 injects microbatch t (valid while t < M)
            inject = x[jnp.minimum(t, n_microbatches - 1)]
            cur = jnp.where(stage_id == 0, inject, state)
            out = stage_fn(params, cur)
            # pass to next stage
            nxt = jax.lax.ppermute(out, axis, perm_fwd)
            # last stage emits microbatch t - (S-1) (valid when >= 0)
            return nxt, out

        state0 = jnp.zeros(mb_shape, x.dtype)
        _, outs = jax.lax.scan(tick, state0, jnp.arange(total))
        # outs: (T, mb, ...) — on the LAST stage, ticks S-1 .. T-1 hold the
        # final outputs of microbatches 0..M-1.
        y = jax.lax.dynamic_slice_in_dim(outs, n_stages - 1, n_microbatches,
                                         axis=0)
        # broadcast the last stage's result to every stage member so the
        # caller sees a replicated output (psum of a one-hot selection).
        is_last = (stage_id == n_stages - 1).astype(y.dtype)
        y = jax.lax.psum(y * is_last, axis)
        return y

    in_specs = (P(axis), P())        # params stacked over stages; x replicated
    out_specs = P()

    try:
        return shard_map(per_stage, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
    except TypeError:  # pre-rename jax: check_vma was called check_rep
        return shard_map(per_stage, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def gpipe_loss(stage_fn: Callable, loss_fn: Callable, mesh: Mesh, axis: str,
               n_microbatches: int):
    """fn(stacked_params, x_mb, target_mb) -> mean loss; differentiable
    end-to-end (grads flow through the ppermute chain)."""
    fwd = gpipe_forward(stage_fn, mesh, axis, n_microbatches)

    def fn(params, x, target):
        y = fwd(params, x)
        return loss_fn(y, target)

    return fn


def stack_stage_params(per_stage_params: list) -> PyTree:
    """[stage0_params, stage1_params, ...] -> stacked pytree (S, ...)."""
    return jax.tree.map(lambda *ls: jnp.stack(ls), *per_stage_params)


def stage_sharding(mesh: Mesh, axis: str, params_stacked: PyTree) -> PyTree:
    return jax.tree.map(
        lambda p: NamedSharding(mesh, P(axis, *([None] * (p.ndim - 1)))),
        params_stacked)


def microbatch(x: jnp.ndarray, n_microbatches: int) -> jnp.ndarray:
    """(B, ...) -> (M, B/M, ...)."""
    b = x.shape[0]
    assert b % n_microbatches == 0
    return x.reshape(n_microbatches, b // n_microbatches, *x.shape[1:])
