"""Property-style invariant sweeps (seeded randomized — hypothesis is not
installed in this container, so the sweeps are explicit and deterministic).

System invariants under test:
  * Algorithm 1 assignments: disjoint groups, memory-feasible groups,
    deterministic, total (with repair) when capacity exists.
  * Disaster recovery: invariants survive arbitrary failure sets.
  * Sharding rules: divisibility always holds, whatever the shape.
  * Data pipeline: shards partition the global batch, replay-exact.
  * Checkpointing: bit-exact roundtrip across dtypes/shapes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh

from repro.checkpoint import restore_pytree, save_pytree
from repro.core import assign as assign_mod
from repro.core import cost_model as cm
from repro.core import train as gnn_train
from repro.core.graph import random_fleet
from repro.data.synthetic import SyntheticConfig, make_batch
from repro.parallel.sharding import ShardingRules, _fit_axes

TASK_SETS = [
    [cm.GPT2_1_5B, cm.BERT_LARGE],
    [cm.T5_11B, cm.GPT2_1_5B, cm.ROBERTA],
]


@pytest.fixture(scope="module")
def gnn_small():
    tasks = TASK_SETS[0]
    cfg = gnn_train.gnn_config_for(tasks)
    ds = gnn_train.make_dataset(3, tasks, n_nodes=16, seed=3, label_frac=0.8)
    # joint default: ~3x the old sequential epoch count (one update/epoch)
    params, _ = gnn_train.train_gnn(cfg, ds, steps=50, lr=0.01)
    return tasks, params, cfg


def _check_invariants(graph, tasks, assignment):
    mem = graph.memory_gb()
    by_name = {t.name: t for t in tasks}
    all_ids = [i for ids in assignment.groups.values() for i in ids]
    assert len(all_ids) == len(set(all_ids)), "groups overlap"
    assert all(0 <= i < graph.n for i in all_ids), "id out of range"
    for name, ids in assignment.groups.items():
        assert sum(mem[i] for i in ids) >= by_name[name].min_memory_gb, \
            f"{name} group under its memory threshold"
    # every task either placed or deferred
    placed = set(assignment.groups) | set(assignment.deferred)
    assert {t.name for t in tasks} <= placed


@pytest.mark.parametrize("seed", range(6))
def test_assignment_invariants_random_fleets(gnn_small, seed):
    tasks, params, cfg = gnn_small
    fleet = random_fleet(10 + 3 * seed, seed=seed)
    a1 = assign_mod.task_assignments(fleet, tasks, params, cfg)
    a2 = assign_mod.task_assignments(fleet, tasks, params, cfg)
    _check_invariants(fleet, tasks, a1)
    assert a1.groups == a2.groups, "assignment must be deterministic"


@pytest.mark.parametrize("seed", range(4))
def test_recovery_invariants(gnn_small, seed):
    tasks, params, cfg = gnn_small
    fleet = random_fleet(14, seed=100 + seed)
    a = assign_mod.task_assignments(fleet, tasks, params, cfg)
    rng = np.random.default_rng(seed)
    failed = sorted(rng.choice(fleet.n, size=3, replace=False).tolist())
    survivors, a2 = assign_mod.recover(fleet, a, failed, tasks, params, cfg)
    assert survivors.n == fleet.n - 3
    _check_invariants(survivors, tasks, a2)


def test_capacity_error_raised(gnn_small):
    tasks, params, cfg = gnn_small
    tiny = random_fleet(2, seed=0)
    huge = [cm.OPT_175B, cm.OPT_175B, cm.OPT_175B, cm.OPT_175B,
            cm.OPT_175B, cm.OPT_175B]
    with pytest.raises(assign_mod.PlacementError):
        assign_mod.task_assignments(tiny, huge, params, cfg)


# ---------------------------------------------------------------------------
# Sharding
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(8))
def test_fit_axes_always_divides(seed):
    rng = np.random.default_rng(seed)
    mesh = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
    for _ in range(50):
        dim = int(rng.integers(1, 70000))
        axes = tuple(rng.permutation(["pod", "data", "model"]))
        fitted = _fit_axes(dim, axes, mesh, set())
        prod = int(np.prod([mesh.shape[a] for a in fitted])) if fitted else 1
        assert dim % prod == 0


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("num_shards", [1, 2, 4, 8])
def test_shards_partition_batch(num_shards):
    from repro.configs import get_config, reduce_for_smoke
    cfg = reduce_for_smoke(get_config("starcoder2-3b"))
    parts = [make_batch(cfg, SyntheticConfig(global_batch=16, seq_len=8,
                                             seed=1, shard_id=i,
                                             num_shards=num_shards), 3)
             for i in range(num_shards)]
    rows = np.concatenate([p["tokens"] for p in parts], axis=0)
    assert rows.shape == (16, 8)
    # distinct shards produce distinct rows (overwhelmingly likely)
    if num_shards > 1:
        assert not np.array_equal(parts[0]["tokens"], parts[1]["tokens"])


# ---------------------------------------------------------------------------
# Checkpoint roundtrip sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32,
                                   jnp.float16])
@pytest.mark.parametrize("shape", [(), (3,), (2, 5), (2, 3, 4)])
def test_checkpoint_roundtrip_sweep(tmp_path, dtype, shape):
    key = jax.random.PRNGKey(hash((str(dtype), shape)) % 2**31)
    if jnp.issubdtype(dtype, jnp.integer):
        leaf = jax.random.randint(key, shape, -5, 100).astype(dtype)
    else:
        leaf = jax.random.normal(key, shape).astype(dtype)
    tree = {"x": leaf, "nested": [leaf, {"y": leaf}]}
    p = str(tmp_path / "ck")
    save_pytree(p, tree)
    back = restore_pytree(p, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(
            np.atleast_1d(np.asarray(a)).view(np.uint8),
            np.atleast_1d(np.asarray(b)).view(np.uint8))
        assert a.dtype == b.dtype and a.shape == b.shape
