"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs; plus a
prefill -> decode-step consistency pass for decoder-bearing archs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduce_for_smoke
from repro.data.synthetic import SyntheticConfig, make_batch
from repro.models.registry import get_api
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

B, S = 2, 16


def _smoke_cfg(arch):
    import dataclasses
    cfg = reduce_for_smoke(get_config(arch))
    return dataclasses.replace(cfg, remat=False)  # faster smoke compile


def _batch(cfg):
    return {k: jnp.asarray(v) for k, v in make_batch(
        cfg, SyntheticConfig(global_batch=B, seq_len=S, seed=0), 0).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = _smoke_cfg(arch)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        loss, metrics = api.loss_and_metrics(p, cfg, batch)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # one optimizer step must stay finite
    opt_cfg = AdamWConfig(learning_rate=1e-3)
    state = adamw_init(params)
    new_params, state, om = adamw_update(opt_cfg, grads, state, params)
    leaves = jax.tree_util.tree_leaves(new_params)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves), \
        f"{arch}: non-finite params after update"
    assert float(om["grad_norm"]) > 0.0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    cfg = _smoke_cfg(arch)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg)
    loss, metrics = api.loss_and_metrics(params, cfg, batch)
    assert loss.shape == ()
    assert np.isfinite(float(metrics["ce"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step(t) after prefill(0..t-1) must match the full forward's
    logits at position t (teacher forcing)."""
    cfg = _smoke_cfg(arch)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(2))
    batch = _batch(cfg)
    tokens = batch["tokens"]
    max_len = S + 4

    if cfg.family == "audio":
        from repro.models import encdec
        enc_out = encdec.encode(params, cfg, batch["frames"])
        full_logits, _ = encdec._decoder(params, cfg, tokens, enc_out)
        last, caches = api.prefill(params, cfg, batch["frames"],
                                   tokens[:, :-1], max_len=max_len)
        step_logits, _ = api.decode_step(params, cfg, tokens[:, -1:],
                                         jnp.int32(S - 1), caches)
    elif cfg.family == "vlm":
        from repro.models import vlm as vlm_mod
        embeds = vlm_mod._embed_multimodal(params, cfg, batch["patches"],
                                           tokens)
        from repro.models import decoder_lm as dlm
        full_logits, _, _ = dlm.forward(params, cfg, embeds=embeds)
        p = batch["patches"].shape[1]
        full_logits = full_logits  # positions include patches
        last, caches = api.prefill(params, cfg, batch["patches"],
                                   tokens[:, :-1], max_len=p + max_len)
        step_logits, _ = api.decode_step(params, cfg, tokens[:, -1:],
                                         jnp.int32(p + S - 1), caches)
        full_logits = full_logits  # compare at final position below
    else:
        from repro.models import decoder_lm as dlm
        full_logits, _, _ = dlm.forward(params, cfg, tokens=tokens)
        last, caches = api.prefill(params, cfg, tokens=tokens[:, :-1],
                                   max_len=max_len)
        step_logits, _ = api.decode_step(params, cfg, tokens[:, -1:],
                                         jnp.int32(S - 1), caches)

    want = full_logits[:, -1:]
    np.testing.assert_allclose(np.asarray(step_logits, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)
    assert np.isfinite(np.asarray(step_logits)).all()
