"""whisper-small [audio] — enc-dec, 12L encoder + 12L decoder, d_model=768
12H (MHA) d_ff=3072 vocab=51865 [arXiv:2212.04356].

The conv/mel frontend is a STUB: input_specs provides precomputed frame
embeddings (B, 1500, 768). Positional adaptation (DESIGN.md SS4): RoPE
replaces whisper's sinusoidal/learned absolute positions so the assigned
decode shapes (32k >> whisper's 448) stay well-defined without resizing a
learned table.
long_500k SKIPPED: full attention.
"""
from repro.configs.base import AttnSpec, LayerSpec, ModelConfig, Segment

_SELF = AttnSpec(n_heads=12, n_kv_heads=12, head_dim=64,
                 rope_theta=10_000.0)
_ENC = AttnSpec(n_heads=12, n_kv_heads=12, head_dim=64,
                rope_theta=10_000.0, causal=False)

N_FRAMES = 1500   # 30 s of audio at the frontend's 50 Hz output


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        d_model=768,
        # true vocab 51,865 — padded to a 256-multiple for TP vocab
        # sharding (see internvl2_1b.py note)
        vocab_size=51_968,
        segments=(
            Segment(count=12,
                    layers=(LayerSpec(kind="attn", mlp="dense", attn=_SELF,
                                      d_ff=3072),)),
        ),
        encoder_segments=(
            Segment(count=12,
                    layers=(LayerSpec(kind="attn", mlp="dense", attn=_ENC,
                                      d_ff=3072),)),
        ),
        encoder_max_len=N_FRAMES,
        norm="layernorm",
        act="gelu",
        tie_embeddings=True,
        sub_quadratic=False,
    )
