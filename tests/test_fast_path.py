"""Fast planning path: numerical equivalence + no-silent-recompile tests.

Covers the four legs of the perf pass:
  * bucketed/padded predict == the unpadded eager forward on every bucket
    boundary (n = bucket, bucket±1);
  * batched training == the sequential reference (bit-exact for the scan
    mode, within tolerance for the vmapped joint mode vs a hand-rolled
    sequential loop of the same full-batch algorithm);
  * fused scaled_spmm (Pallas, interpret mode on CPU) == the jnp oracle;
  * vectorized oracle labeler == the reference Python loops, bit-identical;
plus a trace-counting test proving Algorithm 1 compiles the GNN at most once
per node bucket.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import assign as assign_mod
from repro.core import cost_model as cm
from repro.core import gnn
from repro.core import labels as labels_mod
from repro.core import train as gnn_train
from repro.core.graph import random_fleet
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

SMALL_TASKS = [cm.GPT2_1_5B, cm.BERT_LARGE]


def _tree_allclose(a, b, rtol=1e-4, atol=1e-5):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# bucketed inference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [7, 8, 9, 15, 16, 17, 31, 32, 33])
def test_bucketed_predict_matches_unpadded(n):
    """Padding into the bucket must be inert at n = bucket and bucket±1."""
    fleet = random_fleet(n, seed=n)
    cfg = gnn_train.gnn_config_for(SMALL_TASKS, hidden=48)
    params = gnn.init(jax.random.PRNGKey(2), cfg, 12)
    direct = np.asarray(gnn.apply(
        params, cfg, jnp.asarray(fleet.node_features()),
        jnp.asarray(fleet.latency.astype(np.float32))))
    bucketed = gnn_train.predict_logits(params, cfg, fleet, bucketed=True)
    assert bucketed.shape == (n, cfg.n_classes)
    np.testing.assert_allclose(bucketed, direct, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(
        gnn_train.predict(params, cfg, fleet, bucketed=True),
        np.argmax(direct, axis=-1))


def test_node_mask_makes_padding_inert():
    """Garbage in the padded region must not leak into real logits."""
    fleet = random_fleet(11, seed=3)
    cfg = gnn_train.gnn_config_for(SMALL_TASKS, hidden=32)
    feats = fleet.node_features()
    params = gnn.init(jax.random.PRNGKey(1), cfg, feats.shape[1])
    direct = np.asarray(gnn.apply(params, cfg, jnp.asarray(feats),
                                  jnp.asarray(fleet.latency.astype(np.float32))))
    b = gnn_train.bucket_for(11)
    rng = np.random.default_rng(0)
    pf = rng.normal(size=(b, feats.shape[1])).astype(np.float32)
    pf[:11] = feats
    pl = rng.uniform(1.0, 500.0, size=(b, b)).astype(np.float32)
    pl[:11, :11] = fleet.latency.astype(np.float32)
    nm = np.zeros((b,), np.float32)
    nm[:11] = 1.0
    padded = gnn.apply(params, cfg, jnp.asarray(pf), jnp.asarray(pl),
                       jnp.asarray(nm))
    np.testing.assert_allclose(np.asarray(padded)[:11], direct,
                               rtol=1e-5, atol=1e-5)


def test_task_assignments_compiles_once_per_bucket():
    """A 24-node fleet with 3 tasks re-dispatches Algorithm 1 on shrinking
    subgraphs; the bucketed forward must trace at most once per bucket."""
    tasks = cm.FOUR_TASKS[1:]  # T5 / GPT-2 / BERT fit a 24-node fleet
    cfg = gnn_train.gnn_config_for(tasks, hidden=37)  # unique cfg => fresh cache
    ds = gnn_train.make_dataset(2, tasks, n_nodes=24, seed=11, label_frac=0.8)
    params, _ = gnn_train.train_gnn(cfg, ds, steps=3, lr=0.01)
    fleet = random_fleet(24, seed=6)
    gnn_train.reset_trace_counts()
    assign_mod.task_assignments(fleet, tasks, params, cfg)
    counts = {bucket: c for (c_cfg, bucket), c in gnn_train.trace_counts().items()
              if c_cfg == cfg}
    assert counts, "bucketed path was not exercised"
    assert all(c <= 1 for c in counts.values()), counts
    # subgraphs only shrink from 24, so buckets are a subset of {32, 16, 8}
    assert set(counts) <= {8, 16, 32}, counts


# ---------------------------------------------------------------------------
# batched training
# ---------------------------------------------------------------------------
def test_scan_training_matches_sequential_loop():
    """The stacked scan path must reproduce the sequential per-graph loop's
    final params on a 3-graph dataset (same update trajectory)."""
    cfg = gnn_train.gnn_config_for(SMALL_TASKS)
    ds = gnn_train.make_dataset(3, SMALL_TASKS, n_nodes=12, seed=0,
                                label_frac=0.8)
    p_seq, h_seq = gnn_train.train_gnn(cfg, ds, steps=6, lr=0.01,
                                       mode="sequential")
    p_scan, h_scan = gnn_train.train_gnn(cfg, ds, steps=6, lr=0.01,
                                         mode="scan")
    _tree_allclose(p_seq, p_scan, rtol=1e-4, atol=1e-5)
    for a, b in zip(h_seq, h_scan):
        assert abs(a["loss"] - b["loss"]) < 1e-4
        assert abs(a["accuracy"] - b["accuracy"]) < 1e-6


def test_joint_training_matches_sequential_loop():
    """The vmapped joint mode must match a sequential loop of the same
    algorithm: mean masked loss over graphs, one Adam step per epoch."""
    cfg = gnn_train.gnn_config_for(SMALL_TASKS)
    ds = gnn_train.make_dataset(3, SMALL_TASKS, n_nodes=12, seed=1,
                                label_frac=0.8)
    steps, lr = 5, 0.01

    params = gnn.init(jax.random.PRNGKey(0), cfg, ds[0].feats.shape[1])
    opt_cfg = AdamWConfig(learning_rate=lr, weight_decay=0.0, b2=0.999,
                          grad_clip_norm=0.0)
    opt_state = adamw_init(params)
    grad_fn = jax.grad(lambda p, ex: gnn.loss_fn(
        p, cfg, jnp.asarray(ex.feats), jnp.asarray(ex.lat),
        jnp.asarray(ex.labels), jnp.asarray(ex.mask))[0])
    for _ in range(steps):
        grads = None
        for ex in ds:  # sequential loop over graphs, then one mean update
            g = grad_fn(params, ex)
            grads = g if grads is None else jax.tree.map(jnp.add, grads, g)
        grads = jax.tree.map(lambda x: x / len(ds), grads)
        params, opt_state, _ = adamw_update(opt_cfg, grads, opt_state, params)

    p_joint, _ = gnn_train.train_gnn(cfg, ds, steps=steps, lr=lr, mode="joint")
    # vmapped-mean vs sum-then-divide accumulate in different orders; Adam's
    # rsqrt amplifies the last-ulp drift over the 5 steps
    _tree_allclose(params, p_joint, rtol=1e-3, atol=2e-4)


def test_bucketed_mode_handles_ragged_datasets():
    """Graphs in different node buckets fall back to per-bucket stacking."""
    ds = (gnn_train.make_dataset(2, SMALL_TASKS, n_nodes=10, seed=2,
                                 label_frac=0.8)
          + gnn_train.make_dataset(2, SMALL_TASKS, n_nodes=20, seed=4,
                                   label_frac=0.8))
    cfg = gnn_train.gnn_config_for(SMALL_TASKS)
    params, hist = gnn_train.train_gnn(cfg, ds, steps=8, lr=0.01)  # auto
    assert len(hist) == 8
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert np.isfinite(hist[-1]["accuracy"])


# ---------------------------------------------------------------------------
# fused kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,d,dtype", [
    (8, 22, jnp.float32),
    (46, 15, jnp.float32),
    (128, 213, jnp.float32),
    (200, 64, jnp.float32),
    (46, 12, jnp.bfloat16),
])
def test_scaled_spmm_vs_ref(n, d, dtype):
    from repro.kernels.gcn_spmm import ops as spmm_ops
    from repro.kernels.gcn_spmm import ref as spmm_ref
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    adj = (jax.random.uniform(ks[0], (n, n)) < 0.4).astype(dtype)
    h = jax.random.normal(ks[1], (n, d), dtype)
    r = (jax.random.uniform(ks[2], (n,)) + 0.5).astype(dtype)
    c = (jax.random.uniform(ks[3], (n,)) + 0.5).astype(dtype)
    got = spmm_ops.scaled_spmm(adj, h, r, c)
    want = spmm_ref.scaled_spmm_ref(adj, h, r, c)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)
    # and against the mathematical definition diag(r) @ A @ diag(c) @ H
    dense = (r.astype(jnp.float32)[:, None] * adj.astype(jnp.float32)
             * c.astype(jnp.float32)[None, :]) @ h.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(dense), **tol)


def test_pallas_bucketed_predict_matches_jnp():
    """use_pallas=True (fused normalization, interpret mode on CPU) through
    the bucketed fast path must match the plain jnp forward."""
    fleet = random_fleet(10, seed=8)
    cfg_j = gnn_train.gnn_config_for(SMALL_TASKS, hidden=32, use_pallas=False)
    cfg_p = gnn_train.gnn_config_for(SMALL_TASKS, hidden=32, use_pallas=True)
    params = gnn.init(jax.random.PRNGKey(0), cfg_j, 12)
    out_j = gnn_train.predict_logits(params, cfg_j, fleet, bucketed=True)
    out_p = gnn_train.predict_logits(params, cfg_p, fleet, bucketed=True)
    np.testing.assert_allclose(out_p, out_j, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# vectorized labeler
# ---------------------------------------------------------------------------
def _disconnected_fleet(n=9, seed=0):
    """Three components with no links between them (latency 0 = blocked):
    regression case for the pool-restricted argmin — with every free node at
    inf distance a whole-row argmin would steal already-assigned nodes."""
    from repro.core.graph import ClusterGraph
    base = random_fleet(n, seed=seed)
    lat = base.latency.copy()
    for a in range(n):
        for b in range(n):
            if a // 3 != b // 3:
                lat[a, b] = 0.0
    return ClusterGraph(base.machines, lat)


@pytest.mark.parametrize("n,seed,tasks,fleet_fn", [
    (16, 0, cm.FOUR_TASKS, random_fleet),
    (24, 5, cm.FOUR_TASKS, random_fleet),
    (33, 2, cm.SIX_TASKS, random_fleet),
    (9, 4, cm.FOUR_TASKS[2:], lambda n, seed: _disconnected_fleet(n, seed)),
])
def test_labeler_matches_reference_bit_identically(n, seed, tasks, fleet_fn):
    g = fleet_fn(n, seed=seed)
    comm = cm.make_comm(g)
    fast_g = labels_mod.greedy_partition(g, tasks, comm, seed)
    ref_g = labels_mod.greedy_partition_reference(g, tasks, comm, seed)
    np.testing.assert_array_equal(fast_g, ref_g)
    fast_l = labels_mod.local_search(g, fast_g, tasks, comm, iters=60,
                                     seed=seed)
    ref_l = labels_mod.local_search_reference(g, ref_g, tasks, comm, iters=60,
                                              seed=seed)
    np.testing.assert_array_equal(fast_l, ref_l)


# ---------------------------------------------------------------------------
# vectorized greedy_chain_order
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,seed", [(8, 0), (16, 1), (33, 2), (64, 5)])
def test_chain_order_matches_reference(n, seed):
    g = random_fleet(n, seed=seed)
    ids = list(range(n))
    assert cm.greedy_chain_order(g, ids) \
        == cm.greedy_chain_order_reference(g, ids)
    # non-contiguous, unsorted subsets (how Algorithm 1 groups call it)
    rng = np.random.default_rng(seed)
    sub = [int(i) for i in rng.choice(n, size=max(3, n // 2), replace=False)]
    assert cm.greedy_chain_order(g, sub) \
        == cm.greedy_chain_order_reference(g, sub)


def test_chain_order_handles_blocked_and_tiny_groups():
    from repro.sim.scenarios import blocked_fleet
    g = blocked_fleet(seed=0)
    ids = list(range(g.n))
    assert cm.greedy_chain_order(g, ids) \
        == cm.greedy_chain_order_reference(g, ids)
    assert cm.greedy_chain_order(g, [3]) == [3]
    assert cm.greedy_chain_order(g, [5, 2]) == [5, 2]


def test_chain_order_inf_ties_with_hash_colliding_ids():
    """Unreachable candidates tie at inf latency; with ids that collide in
    a CPython set's hash table (e.g. {0, 32, ...}) the original set-order
    tie-break was unspecified. Both implementations must break such ties to
    the smallest id."""
    from repro.core.graph import ClusterGraph
    base = random_fleet(40, seed=6)
    lat = base.latency.copy()
    # three disconnected islands: {0..12}, {13..25}, {26..39}
    for a in range(40):
        for b in range(40):
            if a // 13 != b // 13:
                lat[a, b] = 0.0
    g = ClusterGraph(base.machines, lat)
    for sub in ([0, 7, 32, 33, 39], [5, 2, 34, 0, 32], list(range(40))):
        fast = cm.greedy_chain_order(g, sub)
        ref = cm.greedy_chain_order_reference(g, sub)
        assert fast == ref, (sub, fast, ref)
